"""Decoder-only transformer LM family: GQA + RoPE + (SWA | full) attention,
SwiGLU/GELU dense MLP or MoE FFN, scan-over-layers with configurable remat.

One implementation covers llama3.2-3b, starcoder2-7b, qwen2-72b, mixtral-8x7b
and llama4-maverick-400b-a17b via `TransformerConfig`.  Forward paths:

  forward()      full-sequence causal LM (training / scoring)
  prefill()      fills a KV cache, returns last-position logits
  decode_step()  one-token decode against the cache (dense or rolling/SWA)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    KVCacheSpec,
    cache_update,
    decode_attention,
    gqa_attention,
)
from repro.models.common import (
    dense,
    dense_init,
    layernorm,
    layernorm_init,
    mlp,
    rmsnorm,
    rmsnorm_init,
    apply_rope,
    softmax_cross_entropy,
)
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.sharding.rules import shard


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    mlp_type: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    window: Optional[int] = None  # sliding-window attention (Mixtral)
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = False
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    attn_impl: str = "xla"  # xla | pallas
    q_chunk: int = 512  # flash chunk sizes (xla_chunked / auto path)
    kv_chunk: int = 1024
    z_loss: float = 1e-4

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.d_q + 2 * d * self.d_kv + self.d_q * d
        if self.qkv_bias:
            attn += self.d_q + 2 * self.d_kv
        if self.moe is not None:
            ffn = self.moe.n_experts * 3 * d * f + d * self.moe.n_experts
        else:
            ffn = (3 if self.mlp_type == "swiglu" else 2) * d * f
        per_layer = attn + ffn + 2 * d
        head = 0 if self.tie_embeddings else d * v
        return v * d + self.n_layers * per_layer + d + head

    def active_param_count(self) -> int:
        """Params touched per token (MoE top-k) — for 6 N_active D."""
        if self.moe is None:
            return self.param_count()
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * self.d_q + 2 * d * self.d_kv + self.d_q * d
        ffn = self.moe.top_k * 3 * d * f + d * self.moe.n_experts
        per_layer = attn + ffn + 2 * d
        head = 0 if self.tie_embeddings else d * v
        return v * d + self.n_layers * per_layer + d + head


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _norm_init(cfg, d):
    return rmsnorm_init(d, cfg.param_dtype) if cfg.norm == "rmsnorm" else layernorm_init(d, cfg.param_dtype)


def _norm(cfg, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


def layer_init(key, cfg: TransformerConfig):
    ks = jax.random.split(key, 8)
    dt = cfg.param_dtype
    p = {
        "ln1": _norm_init(cfg, cfg.d_model),
        "ln2": _norm_init(cfg, cfg.d_model),
        "attn": {
            "wq": dense_init(ks[0], cfg.d_model, cfg.d_q, bias=cfg.qkv_bias, dtype=dt),
            "wk": dense_init(ks[1], cfg.d_model, cfg.d_kv, bias=cfg.qkv_bias, dtype=dt),
            "wv": dense_init(ks[2], cfg.d_model, cfg.d_kv, bias=cfg.qkv_bias, dtype=dt),
            "wo": dense_init(ks[3], cfg.d_q, cfg.d_model, dtype=dt),
        },
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[4], cfg.moe, cfg.d_model, cfg.d_ff, dtype=dt)
    elif cfg.mlp_type == "swiglu":
        p["mlp"] = {
            "w_gate": dense_init(ks[4], cfg.d_model, cfg.d_ff, dtype=dt),
            "w_up": dense_init(ks[5], cfg.d_model, cfg.d_ff, dtype=dt),
            "w_down": dense_init(ks[6], cfg.d_ff, cfg.d_model, dtype=dt),
        }
    else:  # gelu
        p["mlp"] = {
            "w_up": dense_init(ks[4], cfg.d_model, cfg.d_ff, bias=True, dtype=dt),
            "w_down": dense_init(ks[5], cfg.d_ff, cfg.d_model, bias=True, dtype=dt),
        }
    return p


def init_params(key, cfg: TransformerConfig):
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: layer_init(k, cfg))(layer_keys)
    params = {
        "embed": {"w": 0.02 * jax.random.normal(k_embed, (cfg.vocab, cfg.d_model), cfg.param_dtype)},
        "layers": layers,
        "final_norm": _norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab, dtype=cfg.param_dtype)
    return params


def abstract_params(cfg: TransformerConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


# Path-pattern sharding rules (see sharding/rules.py).  FSDP shards the
# leading non-TP dim of every matrix over the data axis; TP dims go to model.
LM_PARAM_RULES = [
    # vocab dim on 'model' (NOT fsdp): the logits gradient is vocab-sharded
    # over 'model', so dW for the (tied) embedding contracts locally and
    # reduce-scatters; (fsdp, tp) here forced a full logits-grad all-gather
    # (250 GiB/device at 4k — EXPERIMENTS.md Perf iteration 0).
    (r"embed/w", ("tp", "fsdp")),
    (r"layers/attn/w[qkv]/w", (None, "fsdp", "tp")),
    (r"layers/attn/w[qkv]/b", (None, "tp")),
    (r"layers/attn/wo/w", (None, "tp", "fsdp")),
    (r"layers/moe/router/w", (None, "fsdp", None)),
    (r"layers/moe/w_(gate|up)", (None, "expert", "fsdp", "tp")),
    (r"layers/moe/w_down", (None, "expert", "tp", "fsdp")),
    (r"layers/mlp/w_(gate|up)/w", (None, "fsdp", "tp")),
    (r"layers/mlp/w_down/w", (None, "tp", "fsdp")),
    (r"layers/mlp/.*/b", (None, None)),
    (r"lm_head/w", ("fsdp", "tp")),
    (r"layers/ln[12]/(scale|bias)", (None, None)),
    (r"final_norm/(scale|bias)", (None,)),
]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _attention_block(cfg, p, x, positions):
    """Full-sequence causal attention (training / prefill); returns (out, k, v)."""
    cd = cfg.compute_dtype
    b, s, _ = x.shape
    h = _norm(cfg, p["ln1"], x)
    # Reshard ON the bf16 tensor: without this constraint GSPMD gathers the
    # norm's f32 upcast over 'model' (2x wire bytes, measured 2 GiB vs 1 GiB
    # per layer at qwen-72b scale) and re-does it per consumer.
    h = shard(h, "batch", "seq", None)
    q = dense(p["attn"]["wq"], h, cd)
    k = dense(p["attn"]["wk"], h, cd)
    v = dense(p["attn"]["wv"], h, cd)
    q = shard(q, "batch", None, "heads")
    k = shard(k, "batch", None, "kv_heads")
    v = shard(v, "batch", None, "kv_heads")
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = gqa_attention(
        q, k, v,
        q_positions=positions, kv_positions=positions,
        window=cfg.window, impl=cfg.attn_impl,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
    )
    out = out.reshape(b, s, cfg.d_q)
    out = shard(out, "batch", None, "heads")
    out = dense(p["attn"]["wo"], out, cd)
    # Constrain the partial-sum output to the RESIDUAL's sharding before the
    # add: GSPMD then lowers psum+slice to a reduce-scatter (128 MiB) instead
    # of a full-width all-reduce (2 GiB at qwen-72b scale, measured).
    out = shard(out, "batch", "seq", "embed")
    return x + out.astype(x.dtype), k, v


def _ffn_block(cfg, p, x):
    cd = cfg.compute_dtype
    h = _norm(cfg, p["ln2"], x)
    h = shard(h, "batch", "seq", None)  # see _attention_block
    if cfg.moe is not None:
        y, aux = moe_apply(p["moe"], cfg.moe, h, cd)
    elif cfg.mlp_type == "swiglu":
        g = dense(p["mlp"]["w_gate"], h, cd)
        u = dense(p["mlp"]["w_up"], h, cd)
        g = shard(g, "batch", None, "mlp")
        u = shard(u, "batch", None, "mlp")
        y = dense(p["mlp"]["w_down"], jax.nn.silu(g) * u, cd)
        aux = {}
    else:
        u = dense(p["mlp"]["w_up"], h, cd)
        u = shard(u, "batch", None, "mlp")
        y = dense(p["mlp"]["w_down"], jax.nn.gelu(u), cd)
        aux = {}
    y = shard(y, "batch", "seq", "embed")  # psum+slice -> reduce-scatter
    return x + y.astype(x.dtype), aux


def _layer(cfg, p, x, positions):
    x, _, _ = _attention_block(cfg, p, x, positions)
    x, aux = _ffn_block(cfg, p, x)
    x = shard(x, "batch", "seq", "embed")
    moe_loss = aux.get("moe_aux", jnp.zeros((), jnp.float32)) + aux.get(
        "moe_z", jnp.zeros((), jnp.float32)
    )
    return x, moe_loss


def _logits(cfg, params, x):
    cd = cfg.compute_dtype
    h = _norm(cfg, params["final_norm"], x)
    # The contraction dim (d_model) must be UNSHARDED going into the vocab
    # projection: with the residual stream feature-sharded over 'model' and
    # the head output vocab-sharded over 'model', GSPMD would otherwise
    # resolve the backward dW einsum by all-gathering the full f32 logits
    # gradient (~250 GiB at 4k x 256 batch) instead of the small h
    # (measured; see EXPERIMENTS.md Perf iteration 0).
    h = shard(h, "batch", None, None)
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", h.astype(cd), params["embed"]["w"].astype(cd),
            preferred_element_type=jnp.float32,
        )
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", h.astype(cd), params["lm_head"]["w"].astype(cd),
            preferred_element_type=jnp.float32,
        )
    return shard(logits, "batch", None, "vocab")


def forward(params, cfg: TransformerConfig, tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Causal LM forward: tokens int32[B, S] -> (logits f32[B, S, V], moe_loss)."""
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x = jnp.take(params["embed"]["w"], tokens, axis=0).astype(cfg.compute_dtype)
    x = shard(x, "batch", None, "embed")

    def body(carry, layer_params):
        x, acc = carry
        x, moe_loss = _layer(cfg, layer_params, x, positions)
        return (x, acc + moe_loss), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, moe_loss), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    return _logits(cfg, params, x), moe_loss


def lm_loss(params, cfg: TransformerConfig, batch) -> Tuple[jax.Array, dict]:
    logits, moe_loss = forward(params, cfg, batch["tokens"])
    loss, metrics = softmax_cross_entropy(
        logits, batch["labels"], batch.get("mask"), z_loss=cfg.z_loss
    )
    total = loss + moe_loss
    metrics["moe_loss"] = moe_loss
    metrics["total_loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def cache_spec(cfg: TransformerConfig, batch: int, seq_len: int) -> KVCacheSpec:
    max_len = seq_len if cfg.window is None else cfg.window
    return KVCacheSpec(
        batch=batch, n_layers=cfg.n_layers, max_len=max_len,
        n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
    )


def prefill(params, cfg: TransformerConfig, tokens: jax.Array, extra_slots: int = 0):
    """Processes the prompt; returns (last-position logits, cache, cur_len).

    The cache stores the last ``min(S, window)`` positions (rolling for SWA).
    ``extra_slots`` reserves empty slots after the prompt for subsequent
    dense-cache decode steps (rolling caches need none).
    """
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x = jnp.take(params["embed"]["w"], tokens, axis=0).astype(cfg.compute_dtype)
    x = shard(x, "batch", None, "embed")
    spec = cache_spec(cfg, b, s)
    m = spec.max_len

    def body(carry, layer_params):
        x, acc = carry
        x2, k, v = _attention_block(cfg, layer_params, x, positions)
        x2, _ = _ffn_block(cfg, layer_params, x2)
        x2 = shard(x2, "batch", None, "embed")
        if cfg.window is None:
            ck, cv = k, v
        else:
            # Rolling layout: slot = position % window for the last
            # min(S, window) tokens; unfilled slots stay zero (masked out by
            # decode_attention's position reconstruction).
            keep = min(s, m)
            slots = positions[-keep:] % m
            zk = jnp.zeros((b, m, cfg.n_kv_heads, cfg.d_head), spec.dtype)
            ck = zk.at[:, slots].set(k[:, -keep:].astype(spec.dtype))
            cv = zk.at[:, slots].set(v[:, -keep:].astype(spec.dtype))
        return (x2, acc), {"k": ck.astype(spec.dtype), "v": cv.astype(spec.dtype)}

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, _), cache = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"]
    )
    if cfg.window is None and extra_slots > 0:
        pad = [(0, 0), (0, 0), (0, extra_slots), (0, 0), (0, 0)]
        cache = {k: jnp.pad(v, pad) for k, v in cache.items()}
    logits = _logits(cfg, params, x[:, -1:, :])
    cur_len = jnp.asarray(s, jnp.int32)
    return logits[:, 0], cache, cur_len


def decode_step(params, cfg: TransformerConfig, cache, tokens: jax.Array, cur_len: jax.Array):
    """One decode step: tokens int32[B, 1] at position cur_len.

    Returns (logits f32[B, V], new_cache, cur_len+1).
    """
    b = tokens.shape[0]
    cd = cfg.compute_dtype
    x = jnp.take(params["embed"]["w"], tokens, axis=0).astype(cd)
    x = shard(x, "batch", None, "embed")
    positions = cur_len[None].astype(jnp.int32)
    rolling = cfg.window is not None

    def body(x, scanned):
        layer_params, ck, cv = scanned
        h = _norm(cfg, layer_params["ln1"], x)
        q = dense(layer_params["attn"]["wq"], h, cd).reshape(b, 1, cfg.n_heads, cfg.d_head)
        k = dense(layer_params["attn"]["wk"], h, cd).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
        v = dense(layer_params["attn"]["wv"], h, cd).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        ck, cv = cache_update(ck, cv, k, v, cur_len, rolling)
        ck = shard(ck, "batch", "kv_seq", None, None)
        cv = shard(cv, "batch", "kv_seq", None, None)
        out = decode_attention(
            q, ck, cv, cur_len, window=cfg.window, impl=cfg.attn_impl
        )
        out = out.reshape(b, 1, cfg.d_q)
        x = x + dense(layer_params["attn"]["wo"], out, cd).astype(x.dtype)
        x, _ = _ffn_block(cfg, layer_params, x)
        return x, {"k": ck, "v": cv}

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    logits = _logits(cfg, params, x)
    return logits[:, 0], new_cache, cur_len + 1
