"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch
(GShard/Switch style, grouped to bound the dispatch tensors).

Tokens are reshaped into groups; within each group an einsum-based
dispatch/combine moves tokens to expert buffers of static capacity
C = ceil(group_size * top_k * capacity_factor / n_experts).  The expert dim
is sharded over the ``expert_batch`` logical axis when divisible (llama4:
128 experts) and replicated otherwise (mixtral: 8 experts, whose d_ff is
tensor-parallel over ``model`` instead); the token->expert movement then
lowers to an all-to-all — the EP pattern.

Aux losses: Switch load-balance loss + router z-loss.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, trunc_normal
from repro.sharding.rules import shard


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 2048
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3


def moe_init(key, cfg: MoEConfig, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std_in = 1.0 / math.sqrt(d_model)
    std_out = 1.0 / math.sqrt(d_ff)
    return {
        "router": dense_init(k1, d_model, cfg.n_experts, dtype=dtype),
        "w_gate": trunc_normal(k2, (cfg.n_experts, d_model, d_ff), std_in, dtype),
        "w_up": trunc_normal(k3, (cfg.n_experts, d_model, d_ff), std_in, dtype),
        "w_down": trunc_normal(k4, (cfg.n_experts, d_ff, d_model), std_out, dtype),
    }


def moe_apply(
    p,
    cfg: MoEConfig,
    x: jax.Array,  # [B, S, D]
    compute_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, dict]:
    b, s, d = x.shape
    e = cfg.n_experts
    tokens = x.reshape(b * s, d)
    n_tok = tokens.shape[0]
    gs = min(cfg.group_size, n_tok)
    assert n_tok % gs == 0, f"tokens {n_tok} % group {gs}"
    g = n_tok // gs
    cap = int(math.ceil(gs * cfg.top_k * cfg.capacity_factor / e))
    cap = max(cap, cfg.top_k)

    xt = tokens.reshape(g, gs, d)
    xt = shard(xt, "batch", None, "embed")

    logits = jnp.einsum(
        "gsd,de->gse", xt.astype(jnp.float32), p["router"]["w"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # [g, gs, e]

    # Top-k gating with renormalization.
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)  # [g, gs, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Positions within each expert buffer, first-come-first-served per group.
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)  # [g, gs, k, e]
    # Order slots so that k=0 choices fill before k=1 across the group.
    flat = onehot.transpose(0, 2, 1, 3).reshape(g, cfg.top_k * gs, e)
    pos = jnp.cumsum(flat, axis=1) - flat  # [g, k*gs, e] rank of each claim
    pos = pos.reshape(g, cfg.top_k, gs, e).transpose(0, 2, 1, 3)  # [g, gs, k, e]
    in_cap = pos < cap
    pos = jnp.minimum(pos, cap - 1).astype(jnp.int32)

    # Dispatch/combine tensors [g, gs, e, cap]; built per top-k slice to keep
    # the largest intermediate at [g, gs, e, cap] (not x top_k).
    keep = onehot * in_cap.astype(jnp.float32)  # [g, gs, k, e]
    dispatch = jnp.zeros((g, gs, e, cap), jnp.float32)
    combine = jnp.zeros((g, gs, e, cap), jnp.float32)
    for kk in range(cfg.top_k):
        slot_oh = jax.nn.one_hot(pos[:, :, kk, :], cap, dtype=jnp.float32)
        contrib = keep[:, :, kk, :, None] * slot_oh  # [g, gs, e, cap]
        dispatch = dispatch + contrib
        combine = combine + top_p[:, :, kk, None, None] * contrib
    dispatch = shard(dispatch, "batch", None, "expert", None)
    combine = shard(combine, "batch", None, "expert", None)

    cd = compute_dtype
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(cd), xt.astype(cd))
    expert_in = shard(expert_in, "expert", "batch", None, "embed")
    h = jnp.einsum("egcd,edf->egcf", expert_in, p["w_gate"].astype(cd))
    u = jnp.einsum("egcd,edf->egcf", expert_in, p["w_up"].astype(cd))
    h = jax.nn.silu(h) * u
    h = shard(h, "expert", "batch", None, "mlp")
    out_e = jnp.einsum("egcf,efd->egcd", h, p["w_down"].astype(cd))
    out_e = shard(out_e, "expert", "batch", None, "embed")
    y = jnp.einsum("egcd,gsec->gsd", out_e, combine.astype(cd))
    y = y.reshape(b, s, d).astype(x.dtype)

    # Aux losses (Switch): fraction routed vs router prob mass per expert.
    me = jnp.mean(onehot[:, :, 0, :], axis=(0, 1))  # top-1 assignment share
    ce = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_loss_weight * e * jnp.sum(me * ce)
    zloss = cfg.router_z_weight * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1))
    )
    dropped = 1.0 - jnp.mean(keep.sum(2).max(-1) > 0)
    return y, {"moe_aux": aux, "moe_z": zloss, "moe_drop_frac": dropped}
