"""MACE — higher-order equivariant message passing (arXiv:2206.07697),
adapted per DESIGN.md: explicit real spherical harmonics to l_max=2, Bessel
radial basis, density-normalized A-basis via segment_sum, and a symmetric
tensor-power B-basis of invariant monomials up to correlation order 3 with
learned couplings.  The invariant readout is exactly SO(3)-invariant
(property-tested under random rotations).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init, l2_loss, mlp, mlp_init, softmax_cross_entropy
from repro.sharding.rules import shard


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    r_cut: float = 5.0
    d_in: int = 16  # species/feature embedding input
    n_classes: int = 0
    compute_dtype: jnp.dtype = jnp.bfloat16


MACE_PARAM_RULES = [
    (r".*(radial|update|readout|embed).*/w", ("fsdp", "tp")),
    (r".*/b", (None,)),
    (r".*coupling", (None, "tp")),
]

N_SH = 9  # (l_max+1)^2 for l_max=2
_L_OF = jnp.asarray([0, 1, 1, 1, 2, 2, 2, 2, 2])  # l of each flat SH index


def spherical_harmonics_l2(rhat: jax.Array) -> jax.Array:
    """Real SH Y_lm for l=0,1,2 of unit vectors rhat [E,3] -> [E,9]."""
    x, y, z = rhat[:, 0], rhat[:, 1], rhat[:, 2]
    c0 = 0.28209479177387814
    c1 = 0.4886025119029199
    return jnp.stack(
        [
            jnp.full_like(x, c0),
            c1 * y,
            c1 * z,
            c1 * x,
            1.0925484305920792 * x * y,
            1.0925484305920792 * y * z,
            0.31539156525252005 * (3.0 * z * z - 1.0),
            1.0925484305920792 * x * z,
            0.5462742152960396 * (x * x - y * y),
        ],
        axis=-1,
    )


def bessel_basis(r: jax.Array, n: int, r_cut: float) -> jax.Array:
    """Radial Bessel basis with smooth cutoff envelope; r [E] -> [E, n]."""
    r = jnp.maximum(r, 1e-6)
    k = jnp.arange(1, n + 1, dtype=jnp.float32) * math.pi / r_cut
    basis = jnp.sqrt(2.0 / r_cut) * jnp.sin(k * r[:, None]) / r[:, None]
    u = jnp.clip(r / r_cut, 0.0, 1.0)
    envelope = 1.0 - 10.0 * u**3 + 15.0 * u**4 - 6.0 * u**5
    return basis * envelope[:, None]


def init_params(key, cfg: MACEConfig):
    ks = jax.random.split(key, 2 + cfg.n_layers * 3)
    c = cfg.d_hidden
    n_l = cfg.l_max + 1
    params = {"embed": {"layer0": dense_init(ks[0], cfg.d_in, c, bias=True)}}
    n_inv = 8  # invariant monomial count (see _invariants)
    for i in range(cfg.n_layers):
        k1, k2, k3 = jax.random.split(ks[1 + i], 3)
        params[f"layer{i}"] = {
            "radial": mlp_init(k1, [cfg.n_rbf, 64, n_l * c]),
            "coupling": 0.1 * jax.random.normal(k2, (n_inv * c, c), jnp.float32),
            "update": mlp_init(k3, [c, c, c]),
        }
    out_d = cfg.n_classes if cfg.n_classes > 0 else 1
    params["readout"] = mlp_init(ks[-1], [c, c, out_d])
    return params


def _invariants(A: jax.Array) -> jax.Array:
    """Invariant monomials of the A-basis up to correlation order 3.

    A: [N, 9, C].  Per-l power spectra (order 2) and their products with the
    l=0 channel (order 3) — all exactly SO(3)-invariant.
    """
    a0 = A[:, 0, :]  # l=0 (order 1)
    p1 = jnp.sum(A[:, 1:4, :] ** 2, axis=1)  # l=1 power (order 2)
    p2 = jnp.sum(A[:, 4:9, :] ** 2, axis=1)  # l=2 power (order 2)
    return jnp.concatenate(
        [a0, p1, p2, a0 * a0, a0 * p1, a0 * p2, a0 * a0 * a0, p1 * p2], axis=-1
    )


def forward(params, cfg: MACEConfig, batch):
    """batch = {features [N,F], positions [N,3], src, dst, edge_mask [E]}."""
    cd = cfg.compute_dtype
    h = dense(params["embed"]["layer0"], batch["features"].astype(cd), cd)  # [N, C]
    x = batch["positions"].astype(jnp.float32)
    src, dst = batch["src"], batch["dst"]
    w = batch["edge_mask"].astype(jnp.float32)
    n, c = h.shape
    n_l = cfg.l_max + 1

    rij = jnp.take(x, dst, axis=0) - jnp.take(x, src, axis=0)
    r = jnp.linalg.norm(rij + 1e-12, axis=-1)
    rhat = rij / (r[:, None] + 1e-12)
    Y = spherical_harmonics_l2(rhat) * w[:, None]  # [E, 9]
    rbf = bessel_basis(r, cfg.n_rbf, cfg.r_cut)  # [E, n_rbf]

    for i in range(cfg.n_layers):
        p = params[f"layer{i}"]
        h = shard(h, "nodes", None)
        R = mlp(p["radial"], rbf.astype(cd), act=jax.nn.silu, compute_dtype=cd)
        R = R.reshape(-1, n_l, c)  # [E, n_l, C]
        R_per_sh = jnp.take(R, _L_OF, axis=1)  # [E, 9, C]
        hj = jnp.take(h, src, axis=0)  # [E, C]
        msg = R_per_sh * Y[:, :, None].astype(cd) * hj[:, None, :]  # [E, 9, C]
        A = jax.ops.segment_sum(msg, dst, num_segments=n)  # [N, 9, C]
        inv = _invariants(A.astype(jnp.float32)).astype(cd)  # [N, 8C]
        b_basis = inv @ p["coupling"].astype(cd)  # [N, C]
        h = h + mlp(p["update"], b_basis, act=jax.nn.silu, compute_dtype=cd)
    return h


def loss_energy(params, cfg: MACEConfig, batch):
    h = forward(params, cfg, batch)
    e_node = mlp(params["readout"], h, act=jax.nn.silu, compute_dtype=cfg.compute_dtype)
    e = jax.ops.segment_sum(
        e_node[:, 0].astype(jnp.float32), batch["graph_ids"],
        num_segments=batch["graph_labels"].shape[0],
    )
    return l2_loss(e, batch["graph_labels"])


def loss_node_class(params, cfg: MACEConfig, batch):
    h = forward(params, cfg, batch)
    logits = mlp(params["readout"], h, act=jax.nn.silu, compute_dtype=cfg.compute_dtype)
    return softmax_cross_entropy(
        logits.astype(jnp.float32), batch["labels"], batch.get("train_mask")
    )
