"""EquiformerV2-style equivariant graph attention (arXiv:2306.12059),
adapted per DESIGN.md §5: node features are spherical channels
(l <= l_max=6 -> 49 components) x C; messages apply eSCN-style m-restricted
SO(2) channel mixing (|m| <= m_max=2) with radial modulation, edge attention
(8 heads) and segment-sum aggregation.  Exact Wigner-D edge alignment is
implemented for l in {0, 1} only; for l >= 2 the SO(2) restriction is applied
in the global frame (documented deviation; the systems-level
compute/memory/communication pattern matches eSCN).

PERF NOTE (EXPERIMENTS.md §Perf, equiformer-v2 x ogb_products): the SO(2)
weights are HEAD-BLOCK-DIAGONAL (each attention head's channel block mixes
independently, matching EquiformerV2's head-partitioned attention).  Because
the per-edge scalars (attention alpha, radial gate) then commute with the
SO(2) linear map, the mixing runs on aggregated NODE features instead of on
every edge:

    sum_e alpha_eh gate_ej (X_src_e W_h) == (sum_e alpha_eh gate_ej X_src_e) W_h

Per-edge work drops from a [E, n_sh, C] x (n_l C)^2 matmul (62M-edge
ogb_products: ~1.3 PFLOP/dev, 22 TiB/dev temps) to a gather-scale-scatter of
[E, n_sh, C] plus [N, ...] matmuls — a ~25x FLOP and ~100x memory reduction
measured in the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import (
    dense,
    dense_init,
    l2_loss,
    mlp,
    mlp_init,
    segment_softmax,
    softmax_cross_entropy,
)
from repro.sharding.rules import shard


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 8
    r_cut: float = 6.0
    d_in: int = 16
    n_classes: int = 0
    compute_dtype: jnp.dtype = jnp.bfloat16

    @property
    def n_sh(self) -> int:
        return (self.l_max + 1) ** 2


EQ2_PARAM_RULES = [
    (r".*(radial|attn_mlp|update|readout|embed).*/w", ("fsdp", "tp")),
    (r".*/b", (None,)),
    (r".*so2_m\d+_(r|i|0)", (None, None, "tp")),
]


def _sh_index(l: int, m: int) -> int:
    return l * l + l + m


def _m_slices(l_max: int, m_max: int) -> List[Tuple[int, np.ndarray, np.ndarray]]:
    """For each m in 0..m_max: (m, flat idx of (l, +m), flat idx of (l, -m))."""
    out = []
    for m in range(0, m_max + 1):
        ls = np.arange(max(m, 0), l_max + 1)
        if m == 0:
            idx = np.asarray([_sh_index(l, 0) for l in ls])
            out.append((m, idx, idx))
        else:
            out.append(
                (
                    m,
                    np.asarray([_sh_index(l, m) for l in ls]),
                    np.asarray([_sh_index(l, -m) for l in ls]),
                )
            )
    return out


def _row_slice_map(l_max: int, m_max: int) -> np.ndarray:
    """int32[n_sh]: which radial-gate slice modulates each (l, m) row;
    -1 = row does not participate in SO(2) mixing (pass-through)."""
    n_sh = (l_max + 1) ** 2
    out = np.full(n_sh, -1, np.int32)
    for j, (m, idx_p, idx_n) in enumerate(_m_slices(l_max, m_max)):
        out[idx_p] = j
        out[idx_n] = j
    return out


def init_params(key, cfg: EquiformerV2Config):
    ks = jax.random.split(key, cfg.n_layers + 2)
    c = cfg.d_hidden
    h = cfg.n_heads
    ch = c // h
    params = {"embed": {"layer0": dense_init(ks[0], cfg.d_in, c, bias=True)}}
    slices = _m_slices(cfg.l_max, cfg.m_max)
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[i + 1], 8)
        layer = {
            "radial": mlp_init(kk[0], [cfg.n_rbf, 64, len(slices)]),
            "attn_mlp": mlp_init(kk[1], [2 * c + cfg.n_rbf, c, cfg.n_heads]),
            "update": mlp_init(kk[2], [2 * c, c, c]),
        }
        for j, (m, idx_p, _) in enumerate(slices):
            n_l = len(idx_p)
            dim = n_l * ch  # head-block-diagonal: mixes within one head block
            std = 1.0 / np.sqrt(dim)
            if m == 0:
                layer[f"so2_m{m}_0"] = std * jax.random.normal(kk[3 + j], (h, dim, dim))
            else:
                layer[f"so2_m{m}_r"] = std * jax.random.normal(kk[3 + j], (h, dim, dim))
                layer[f"so2_m{m}_i"] = std * jax.random.normal(
                    jax.random.fold_in(kk[3 + j], 7), (h, dim, dim)
                )
        params[f"layer{i}"] = layer
    out_d = cfg.n_classes if cfg.n_classes > 0 else 1
    params["readout"] = mlp_init(ks[-1], [c, c, out_d])
    return params


def _so2_mix_nodes(layer, cfg, Z):
    """Head-block-diagonal SO(2) mixing on AGGREGATED node features.

    Z: [N, n_sh, C] (already attention/gate-weighted sums of neighbors).
    """
    cd = cfg.compute_dtype
    n, n_sh, c = Z.shape
    h = cfg.n_heads
    ch = c // h
    out = Z

    def blockify(rows):  # [N, n_l, C] -> [N, H, n_l*ch]
        n_l = rows.shape[1]
        return (
            rows.reshape(n, n_l, h, ch).transpose(0, 2, 1, 3).reshape(n, h, n_l * ch)
        )

    def unblockify(y, n_l):  # [N, H, n_l*ch] -> [N, n_l, C]
        return (
            y.reshape(n, h, n_l, ch).transpose(0, 2, 1, 3).reshape(n, n_l, c)
        )

    for j, (m, idx_p, idx_n) in enumerate(_m_slices(cfg.l_max, cfg.m_max)):
        n_l = len(idx_p)
        if m == 0:
            s = blockify(Z[:, idx_p, :])
            y = jnp.einsum(
                "nha,hab->nhb", s, layer["so2_m0_0"].astype(cd)
            )
            out = out.at[:, idx_p, :].set(unblockify(y, n_l))
        else:
            sp = blockify(Z[:, idx_p, :])
            sn = blockify(Z[:, idx_n, :])
            wr = layer[f"so2_m{m}_r"].astype(cd)
            wi = layer[f"so2_m{m}_i"].astype(cd)
            yp = jnp.einsum("nha,hab->nhb", sp, wr) - jnp.einsum(
                "nha,hab->nhb", sn, wi
            )
            yn = jnp.einsum("nha,hab->nhb", sp, wi) + jnp.einsum(
                "nha,hab->nhb", sn, wr
            )
            out = out.at[:, idx_p, :].set(unblockify(yp, n_l))
            out = out.at[:, idx_n, :].set(unblockify(yn, n_l))
    return out


def forward(params, cfg: EquiformerV2Config, batch):
    """batch = {features [N,F], positions [N,3], src, dst, edge_mask [E]}."""
    from repro.models.gnn.mace import bessel_basis

    cd = cfg.compute_dtype
    n = batch["features"].shape[0]
    c = cfg.d_hidden
    h0 = dense(params["embed"]["layer0"], batch["features"].astype(cd), cd)  # [N, C]
    X = jnp.zeros((n, cfg.n_sh, c), cd).at[:, 0, :].set(h0)
    x = batch["positions"].astype(jnp.float32)
    src, dst = batch["src"], batch["dst"]
    w = batch["edge_mask"].astype(jnp.float32)

    rij = jnp.take(x, dst, axis=0) - jnp.take(x, src, axis=0)
    r = jnp.linalg.norm(rij + 1e-12, axis=-1)
    rbf = (bessel_basis(r, cfg.n_rbf, cfg.r_cut) * w[:, None]).astype(cd)  # [E, n_rbf]
    n_heads = cfg.n_heads
    ch_per_head = c // n_heads
    row_slice = jnp.asarray(_row_slice_map(cfg.l_max, cfg.m_max))  # [n_sh]

    for i in range(cfg.n_layers):
        p = params[f"layer{i}"]
        X = shard(X, "nodes", None, None)
        radial_gate = mlp(p["radial"], rbf, act=jax.nn.silu, compute_dtype=cd)

        # Edge attention from invariant (l=0) channels.
        s_i = jnp.take(X[:, 0, :], dst, axis=0)
        s_j = jnp.take(X[:, 0, :], src, axis=0)
        scores = mlp(
            p["attn_mlp"], jnp.concatenate([s_i, s_j, rbf], -1),
            act=jax.nn.silu, compute_dtype=cd,
        ).astype(jnp.float32)  # [E, H]
        scores = jnp.where(w[:, None] > 0, scores, -jnp.inf)
        alpha = jax.vmap(lambda s: segment_softmax(s, dst, n), in_axes=1, out_axes=1)(
            scores
        )  # [E, H]
        alpha = (alpha * w[:, None]).astype(cd)

        # Per-edge scalars commute with the head-block-diagonal SO(2) mix, so
        # weight at the EDGE, mix at the NODE (see module docstring).
        a_ch = jnp.repeat(alpha, ch_per_head, axis=1)  # [E, C]
        row_gate = jnp.where(
            row_slice[None, :] >= 0,
            jnp.take_along_axis(
                radial_gate,
                jnp.broadcast_to(
                    jnp.maximum(row_slice, 0)[None, :], (a_ch.shape[0], cfg.n_sh)
                ),
                axis=1,
            ),
            1.0,
        )  # [E, n_sh]
        Xs = jnp.take(X, src, axis=0)  # [E, n_sh, C]  (read-once gather)
        weighted = Xs * row_gate[..., None] * a_ch[:, None, :]
        Z = jax.ops.segment_sum(weighted, dst, num_segments=n)  # [N, n_sh, C]
        Z = shard(Z, "nodes", None, None)
        agg = _so2_mix_nodes(p, cfg, Z)  # [N, n_sh, C] node-side matmuls

        # Node update: equivariant residual + invariant-gated MLP on l=0.
        X = X + agg
        s = jnp.concatenate([X[:, 0, :], agg[:, 0, :]], -1)
        X = X.at[:, 0, :].add(mlp(p["update"], s, act=jax.nn.silu, compute_dtype=cd))
        # Per-l RMS normalization (keeps deep stacks stable).
        norm = jnp.sqrt(jnp.mean(jnp.square(X.astype(jnp.float32)), axis=(1, 2), keepdims=True) + 1e-6)
        X = (X.astype(jnp.float32) / norm).astype(cd)
    return X


def loss_energy(params, cfg: EquiformerV2Config, batch):
    X = forward(params, cfg, batch)
    e_node = mlp(params["readout"], X[:, 0, :], act=jax.nn.silu, compute_dtype=cfg.compute_dtype)
    e = jax.ops.segment_sum(
        e_node[:, 0].astype(jnp.float32), batch["graph_ids"],
        num_segments=batch["graph_labels"].shape[0],
    )
    return l2_loss(e, batch["graph_labels"])


def loss_node_class(params, cfg: EquiformerV2Config, batch):
    X = forward(params, cfg, batch)
    logits = mlp(params["readout"], X[:, 0, :], act=jax.nn.silu, compute_dtype=cfg.compute_dtype)
    return softmax_cross_entropy(
        logits.astype(jnp.float32), batch["labels"], batch.get("train_mask")
    )
