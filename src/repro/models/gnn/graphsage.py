"""GraphSAGE (mean aggregator) — full-batch (edge-list segment_mean) and
sampled-minibatch (layered fanout blocks from graph/sampler.py) paths.

The full-batch path is the same gather -> segment-reduce substrate as the
densest-subgraph core (see DESIGN.md §5: shared kernel regime).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    dense,
    dense_init,
    segment_mean,
    softmax_cross_entropy,
)
from repro.sharding.rules import shard


@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str = "graphsage-reddit"
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 128
    n_classes: int = 41
    fanouts: Tuple[int, ...] = (15, 10)
    compute_dtype: jnp.dtype = jnp.bfloat16


SAGE_PARAM_RULES = [
    (r"layer\d+/(w_self|w_neigh)/w", ("fsdp", "tp")),
    (r"head/w", ("fsdp", "tp")),
    (r".*/b", (None,)),
]


def init_params(key, cfg: SAGEConfig):
    ks = jax.random.split(key, cfg.n_layers + 1)
    params = {}
    d = cfg.d_in
    for i in range(cfg.n_layers):
        params[f"layer{i}"] = {
            "w_self": dense_init(ks[i], d, cfg.d_hidden, bias=True),
            "w_neigh": dense_init(jax.random.fold_in(ks[i], 1), d, cfg.d_hidden),
        }
        d = cfg.d_hidden
    out_d = cfg.n_classes if cfg.n_classes > 0 else 1  # 0 => regression head
    params["head"] = dense_init(ks[-1], d, out_d, bias=True)
    return params


def _sage_layer(p, h_self, h_neigh_mean, cd, act=True):
    y = dense(p["w_self"], h_self, cd) + dense(p["w_neigh"], h_neigh_mean, cd)
    return jax.nn.relu(y) if act else y


def forward_full(params, cfg: SAGEConfig, batch):
    """Full-batch: batch = {features [N,F], src, dst, edge_mask}."""
    cd = cfg.compute_dtype
    h = batch["features"].astype(cd)
    n = h.shape[0]
    src, dst = batch["src"], batch["dst"]
    w = batch["edge_mask"].astype(cd)
    for i in range(cfg.n_layers):
        h = shard(h, "nodes", None)
        msgs = jnp.take(h, src, axis=0) * w[:, None]
        agg = segment_mean(msgs, dst, n)
        h = _sage_layer(params[f"layer{i}"], h, agg, cd)
    h = shard(h, "nodes", None)
    return dense(params["head"], h, cd).astype(jnp.float32)


def forward_sampled(params, cfg: SAGEConfig, batch):
    """Minibatch: layered fanout gathers (GraphSAGE's own sampling scheme).

    batch = {feat_table [N,F], hop0 [R], hop1 [R,f1], hop2 [R,f1,f2],
             hop1_mask, hop2_mask, labels [R]}
    """
    cd = cfg.compute_dtype
    ft = batch["feat_table"]
    f0 = jnp.take(ft, batch["hop0"], axis=0).astype(cd)  # [R, F]
    f1 = jnp.take(ft, batch["hop1"], axis=0).astype(cd)  # [R, f1, F]
    f2 = jnp.take(ft, batch["hop2"], axis=0).astype(cd)  # [R, f1, f2, F]
    m1 = batch["hop1_mask"].astype(cd)[..., None]
    m2 = batch["hop2_mask"].astype(cd)[..., None]

    def masked_mean(x, m, axis):
        return (x * m).sum(axis) / jnp.maximum(m.sum(axis), 1.0)

    l0 = params["layer0"]
    h1 = _sage_layer(l0, f1, masked_mean(f2, m2, axis=2), cd)  # [R, f1, d]
    h0 = _sage_layer(l0, f0, masked_mean(f1, m1, axis=1), cd)  # [R, d]
    l1 = params["layer1"]
    hr = _sage_layer(l1, h0, masked_mean(h1, m1, axis=1), cd)  # [R, d]
    return dense(params["head"], hr, cd).astype(jnp.float32)


def loss_full(params, cfg: SAGEConfig, batch):
    logits = forward_full(params, cfg, batch)
    return softmax_cross_entropy(logits, batch["labels"], batch.get("train_mask"))


def loss_sampled(params, cfg: SAGEConfig, batch):
    logits = forward_sampled(params, cfg, batch)
    return softmax_cross_entropy(logits, batch["labels"])


def loss_pooled(params, cfg: SAGEConfig, batch):
    """Batched-small-graphs (molecule shape): mean-pool per graph, regress."""
    from repro.models.common import l2_loss

    out = forward_full(params, cfg, batch)  # [N, 1]
    n_graphs = batch["graph_labels"].shape[0]
    pooled = segment_mean(out, batch["graph_ids"], n_graphs)[:, 0]
    return l2_loss(pooled, batch["graph_labels"])
