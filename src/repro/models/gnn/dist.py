"""Tile-partitioned message passing: the paper's MapReduce edge partitioning
as a differentiable GNN training primitive.

GSPMD's default lowering of `segment_sum(X[src] * w, dst)` with randomly
sharded edges produces FULL-node-state partial sums on every device followed
by an all-reduce — O(N · width) wire bytes per device per layer (the
equiformer x ogb_products §Perf bottleneck).  This module co-partitions
edges with their DESTINATION node tile (the 'shuffle done once' of
graph/partition.py / paper §5.2), so inside ``shard_map``:

  forward:   all-gather X (one ring AG of the node state)
             -> gather/scale local in-edges -> LOCAL segment_sum.  No psum.
  backward:  dX needs edges grouped by SOURCE -> a second static tiling of
             the same edges; one ring AG of dZbar, local scatter.  dw is
             computed on the in-tiling where dZbar is already local.

Wire bytes per layer drop from 2·|X|·(g-1)/g (AR of f32 partials) to
|X|·(g-1)/g bf16 each way — measured 3.3x on the ogb_products shape (see
EXPERIMENTS.md §Perf, equiformer iteration 3).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class EdgeTiling:
    """Static two-way tiling of a directed edge list over D devices.

    Node tile d owns rows [d*tile_n, (d+1)*tile_n).  ``in_*`` buckets edges
    by dst tile (forward), ``out_*`` by src tile (backward); both padded to
    the max per-tile count (mask via w=0 slots handled by the caller's
    weights; padding slots point at local row 0 with weight 0).
    """

    in_src: np.ndarray  # int32[D, E_in]  global src ids
    in_dst_local: np.ndarray  # int32[D, E_in]  dst - tile_start
    in_eid: np.ndarray  # int32[D, E_in]  original edge index (-1 pad)
    out_dst: np.ndarray  # int32[D, E_out] global dst ids
    out_src_local: np.ndarray  # int32[D, E_out]
    out_eid: np.ndarray  # int32[D, E_out]
    tile_n: int
    n_nodes_padded: int


def build_edge_tiling(
    src: np.ndarray, dst: np.ndarray, n_nodes: int, n_devices: int,
    pad_multiple: int = 8,
) -> EdgeTiling:
    n_pad = ((n_nodes + n_devices - 1) // n_devices) * n_devices
    tile_n = n_pad // n_devices

    def bucket(key: np.ndarray, other: np.ndarray):
        tile = key // tile_n
        order = np.argsort(tile, kind="stable")
        key_s, other_s, eid_s = key[order], other[order], order
        counts = np.bincount(tile, minlength=n_devices)
        width = int(counts.max(initial=0))
        width = max(((width + pad_multiple - 1) // pad_multiple) * pad_multiple,
                    pad_multiple)
        loc = np.zeros((n_devices, width), np.int32)
        oth = np.zeros((n_devices, width), np.int32)
        eid = np.full((n_devices, width), -1, np.int32)
        starts = np.concatenate([[0], np.cumsum(counts)])
        for d in range(n_devices):
            s, c = starts[d], counts[d]
            loc[d, :c] = (key_s[s : s + c] - d * tile_n).astype(np.int32)
            oth[d, :c] = other_s[s : s + c].astype(np.int32)
            eid[d, :c] = eid_s[s : s + c].astype(np.int32)
        return loc, oth, eid

    in_dst_local, in_src, in_eid = bucket(np.asarray(dst, np.int64),
                                          np.asarray(src, np.int64))
    out_src_local, out_dst, out_eid = bucket(np.asarray(src, np.int64),
                                             np.asarray(dst, np.int64))
    return EdgeTiling(
        in_src=in_src, in_dst_local=in_dst_local, in_eid=in_eid,
        out_dst=out_dst, out_src_local=out_src_local, out_eid=out_eid,
        tile_n=tile_n, n_nodes_padded=n_pad,
    )


def make_tiled_neighbor_sum(tiling: EdgeTiling, mesh: Mesh, axes: Tuple[str, ...]):
    """Returns ``f(X, w_edge) -> Z`` with Z[n] = sum_{e: dst=n} w_e X[src_e].

    X: [N_pad, ...] node features sharded over ``axes`` on dim 0;
    w_edge: float[E] per-ORIGINAL-edge differentiable weights (replicated).
    Z has X's shape/sharding.  Gradients flow to both X and w_edge.
    """
    spec_x = P(axes)
    spec_r = P()
    in_src = jnp.asarray(tiling.in_src)
    in_dst = jnp.asarray(tiling.in_dst_local)
    in_eid = jnp.asarray(tiling.in_eid)
    out_dst = jnp.asarray(tiling.out_dst)
    out_src = jnp.asarray(tiling.out_src_local)
    out_eid = jnp.asarray(tiling.out_eid)
    tile_n = tiling.tile_n
    n_edges_sig = None  # closed over at call time

    def _w_slot(w_edge, eid):
        safe = jnp.maximum(eid, 0)
        return jnp.where(eid >= 0, w_edge[safe], 0.0)

    def fwd_local(x_local, w_edge, src_g, dst_l, eid):
        # [1, E] leading shard dim from shard_map on the tiling arrays.
        src_g, dst_l, eid = src_g[0], dst_l[0], eid[0]
        xg = jax.lax.all_gather(x_local, axes, axis=0, tiled=True)  # [N, ...]
        w = _w_slot(w_edge, eid)
        msgs = xg[src_g] * w.reshape((-1,) + (1,) * (xg.ndim - 1))
        return jax.ops.segment_sum(msgs, dst_l, num_segments=tile_n)

    def bwd_x_local(dz_local, w_edge, dst_g, src_l, eid):
        dst_g, src_l, eid = dst_g[0], src_l[0], eid[0]
        dzg = jax.lax.all_gather(dz_local, axes, axis=0, tiled=True)
        w = _w_slot(w_edge, eid)
        msgs = dzg[dst_g] * w.reshape((-1,) + (1,) * (dzg.ndim - 1))
        return jax.ops.segment_sum(msgs, src_l, num_segments=tile_n)

    def bwd_w_local(x_local, dz_local, src_g, dst_l, eid, n_edges):
        # dw_e = <X[src_e], dZ[dst_e]>; dst is LOCAL in the in-tiling.
        src_g, dst_l, eid = src_g[0], dst_l[0], eid[0]
        xg = jax.lax.all_gather(x_local, axes, axis=0, tiled=True)
        contrib = jnp.sum(
            (xg[src_g] * dz_local[dst_l]).reshape(src_g.shape[0], -1), axis=-1
        )
        safe = jnp.maximum(eid, 0)
        dw_partial = jnp.zeros((n_edges,), contrib.dtype).at[safe].add(
            jnp.where(eid >= 0, contrib, 0.0)
        )
        return jax.lax.psum(dw_partial, axes)  # edges live on one tile each

    sm = partial(shard_map, mesh=mesh, check_vma=False)

    @jax.custom_vjp
    def f(x, w_edge):
        return sm(
            fwd_local,
            in_specs=(spec_x, spec_r, spec_x, spec_x, spec_x),
            out_specs=spec_x,
        )(x, w_edge, in_src, in_dst, in_eid)

    def f_fwd(x, w_edge):
        return f(x, w_edge), (x, w_edge)

    def f_bwd(res, dz):
        x, w_edge = res
        dx = sm(
            bwd_x_local,
            in_specs=(spec_x, spec_r, spec_x, spec_x, spec_x),
            out_specs=spec_x,
        )(dz, w_edge, out_dst, out_src, out_eid)
        dw = sm(
            partial(bwd_w_local, n_edges=w_edge.shape[0]),
            in_specs=(spec_x, spec_x, spec_x, spec_x, spec_x),
            out_specs=spec_r,
        )(x, dz, in_src, in_dst, in_eid)
        return dx.astype(x.dtype), dw.astype(w_edge.dtype)

    f.defvjp(f_fwd, f_bwd)
    return f


def neighbor_sum_reference(x, w_edge, src, dst, n_nodes):
    """GSPMD-default oracle: gather -> scale -> segment_sum."""
    msgs = x[src] * w_edge.reshape((-1,) + (1,) * (x.ndim - 1))
    return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
