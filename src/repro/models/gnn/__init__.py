from repro.models.gnn import egnn, equiformer_v2, graphsage, mace  # noqa: F401
