"""EGNN — E(n)-equivariant graph network (Satorras et al., arXiv:2102.09844).

Exactly the paper's layer:
  m_ij  = phi_e(h_i, h_j, ||x_i - x_j||^2)
  x_i'  = x_i + C * sum_j (x_i - x_j) phi_x(m_ij)
  h_i'  = phi_h(h_i, sum_j m_ij)

Equivariance is exact and property-tested (tests/test_gnn_models.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense, l2_loss, mlp, mlp_init, softmax_cross_entropy, dense_init
from repro.sharding.rules import shard


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_in: int = 64
    d_hidden: int = 64
    n_classes: int = 0  # 0 => energy regression readout
    compute_dtype: jnp.dtype = jnp.bfloat16


EGNN_PARAM_RULES = [
    (r".*(phi_e|phi_h|phi_x|readout|embed)/layer\d+/w", ("fsdp", "tp")),
    (r".*/b", (None,)),
]


def init_params(key, cfg: EGNNConfig):
    ks = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    params = {"embed": {"layer0": dense_init(ks[0], cfg.d_in, d, bias=True)}}
    for i in range(cfg.n_layers):
        ki = jax.random.split(ks[i + 1], 3)
        params[f"layer{i}"] = {
            "phi_e": mlp_init(ki[0], [2 * d + 1, d, d]),
            "phi_x": mlp_init(ki[1], [d, d, 1]),
            "phi_h": mlp_init(ki[2], [2 * d, d, d]),
        }
    out_d = cfg.n_classes if cfg.n_classes > 0 else 1
    params["readout"] = mlp_init(ks[-1], [d, d, out_d])
    return params


def forward(params, cfg: EGNNConfig, batch):
    """batch = {features [N,F], positions [N,3], src, dst, edge_mask [E]}.

    Returns (h [N,d], x [N,3]) after all layers.
    """
    cd = cfg.compute_dtype
    h = dense(params["embed"]["layer0"], batch["features"].astype(cd), cd)
    x = batch["positions"].astype(jnp.float32)
    src, dst = batch["src"], batch["dst"]
    w = batch["edge_mask"].astype(jnp.float32)
    n = h.shape[0]

    for i in range(cfg.n_layers):
        p = params[f"layer{i}"]
        h = shard(h, "nodes", None)
        hi = jnp.take(h, dst, axis=0)
        hj = jnp.take(h, src, axis=0)
        rij = jnp.take(x, dst, axis=0) - jnp.take(x, src, axis=0)  # [E, 3]
        d2 = jnp.sum(rij * rij, axis=-1, keepdims=True)
        m = mlp(p["phi_e"], jnp.concatenate([hi, hj, d2.astype(cd)], -1),
                act=jax.nn.silu, compute_dtype=cd, final_act=True)
        m = m * w[:, None].astype(cd)
        # Coordinate update (float32 for stability, normalized by distance).
        coef = mlp(p["phi_x"], m, act=jax.nn.silu, compute_dtype=cd).astype(jnp.float32)
        upd = rij / (jnp.sqrt(d2) + 1.0) * coef * w[:, None]
        x = x + jax.ops.segment_sum(upd, dst, num_segments=n) / (
            jax.ops.segment_sum(w, dst, num_segments=n)[:, None] + 1.0
        )
        # Feature update.
        agg = jax.ops.segment_sum(m, dst, num_segments=n)
        h = h + mlp(p["phi_h"], jnp.concatenate([h, agg], -1), act=jax.nn.silu, compute_dtype=cd)
    return h, x


def readout_energy(params, cfg: EGNNConfig, h, graph_ids, n_graphs):
    e_node = mlp(params["readout"], h, act=jax.nn.silu, compute_dtype=cfg.compute_dtype)
    return jax.ops.segment_sum(e_node[:, 0].astype(jnp.float32), graph_ids, num_segments=n_graphs)


def loss_energy(params, cfg: EGNNConfig, batch):
    h, _ = forward(params, cfg, batch)
    e = readout_energy(params, cfg, h, batch["graph_ids"], batch["graph_labels"].shape[0])
    return l2_loss(e, batch["graph_labels"])


def loss_node_class(params, cfg: EGNNConfig, batch):
    h, _ = forward(params, cfg, batch)
    logits = mlp(params["readout"], h, act=jax.nn.silu, compute_dtype=cfg.compute_dtype)
    return softmax_cross_entropy(
        logits.astype(jnp.float32), batch["labels"], batch.get("train_mask")
    )
