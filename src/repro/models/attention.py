"""Attention ops for the LM family: GQA, causal / sliding-window masks, KV
cache for decode.  ``impl='xla'`` is the dense jnp path (used by the dry-run:
the HLO represents the real computation); ``impl='pallas'`` dispatches to the
fused Pallas kernel (TPU target, validated in interpret mode on CPU).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard

NEG_INF = -2.0e38


def _causal_window_mask(
    q_pos: jax.Array, kv_pos: jax.Array, window: Optional[int]
) -> jax.Array:
    """bool[Q, K] allowed-attention mask: kv_pos <= q_pos (& within window)."""
    ok = kv_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= kv_pos[None, :] > q_pos[:, None] - window
    return ok


def gqa_attention(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Sk, Hkv, D]
    v: jax.Array,  # [B, Sk, Hkv, D]
    *,
    q_positions: jax.Array,  # int32[Sq] absolute positions of queries
    kv_positions: jax.Array,  # int32[Sk]
    kv_valid: Optional[jax.Array] = None,  # bool[B, Sk] cache-slot validity
    window: Optional[int] = None,
    impl: str = "xla",
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Grouped-query attention with causal (+ optional sliding-window) mask.

    impl:
      'xla'         dense S^2 scores (short sequences / decode)
      'xla_chunked' flash-style online-softmax double scan (O(chunk^2) memory)
      'pallas'      fused Pallas TPU kernel (interpret-mode on CPU)
      'auto'        chunked when Sq*Sk is large, dense otherwise
    """
    if impl == "auto":
        impl = "xla_chunked" if q.shape[1] * k.shape[1] > 4096 * 2048 else "xla"
    if impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(
            q, k, v,
            q_positions=q_positions, kv_positions=kv_positions,
            kv_valid=kv_valid, window=window,
        )
    if impl == "xla_chunked":
        # Tensor-parallel layout: expand KV to the full query-head count and
        # shard attention on heads.  GQA head counts (8-40) rarely divide the
        # 16-way model axis; uneven head sharding costs <= 1.33x padding,
        # versus 16x if attention compute were replicated (measured:
        # model_flops_ratio 0.12 -> ~0.4; EXPERIMENTS.md Perf iteration 0).
        # KV expansion costs g x KV bandwidth, negligible next to scores.
        b, sq, hq, d = q.shape
        hkv = k.shape[2]
        if hkv != hq:
            g = hq // hkv
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        q = shard(q, "batch", None, "heads4", None)
        k = shard(k, "batch", None, "heads4", None)
        v = shard(v, "batch", None, "heads4", None)
        out = _chunked_gqa(
            q, k, v,
            q_positions=q_positions, kv_positions=kv_positions,
            kv_valid=kv_valid, window=window,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        return shard(out, "batch", None, "heads4", None)

    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    qg = q.reshape(b, sq, hkv, g, d)
    # [B, Hkv, G, Sq, Sk]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    mask = _causal_window_mask(q_positions, kv_positions, window)  # [Sq, Sk]
    if kv_valid is not None:
        mask = mask[None] & kv_valid[:, None, :]  # [B, Sq, Sk]
        scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    else:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, hq, d)


def _chunked_gqa(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Sk, Hkv, D]
    v: jax.Array,  # [B, Sk, Hkv, D]
    *,
    q_positions: jax.Array,  # int32[Sq]
    kv_positions: jax.Array,  # int32[Sk]
    kv_valid: Optional[jax.Array],  # bool[B, Sk] or None
    window: Optional[int],
    q_chunk: int,
    kv_chunk: int,
) -> jax.Array:
    """Flash attention (forward + custom backward) as a double chunk scan.

    Peak live memory is O(q_chunk * kv_chunk) scores instead of O(Sq * Sk),
    in BOTH directions: the backward is a custom VJP that recomputes the
    probabilities per chunk pair from the saved (out, logsumexp) — letting
    jax differentiate the forward scan instead would stack every chunk's
    score matrix (O(Sq*Sk) residuals, ~200 GiB/layer at 4k seq).  This is
    the same schedule the Pallas TPU kernel implements in VMEM; this XLA
    version doubles as its reference oracle.
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0

    # Pad sequence dims to chunk multiples; padding is masked out.
    pad_q = (-sq) % q_chunk
    pad_k = (-sk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad_q), constant_values=-(2**30))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad_k), constant_values=2**30)
        if kv_valid is None:
            base = jnp.arange(sk + pad_k) < sk
            kv_valid = jnp.broadcast_to(base[None], (b, sk + pad_k))
        else:
            kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad_k)))
    fn = _flash_fn(window, q_chunk, kv_chunk, bool(kv_valid is not None))
    out = fn(q, k, v, q_positions, kv_positions, kv_valid)
    return out[:, :sq]


def _chunk_mask(qpos_blk, kpos_blk, valid_blk, window):
    """bool[(b?),qc,kc] allowed mask for one (q, kv) chunk pair."""
    ok = kpos_blk[None, :] <= qpos_blk[:, None]  # causal
    if window is not None:
        ok &= kpos_blk[None, :] > qpos_blk[:, None] - window
    ok = ok[None, None, None]  # [1,1,1,qc,kc]
    if valid_blk is not None:
        ok = ok & valid_blk[:, None, None, None, :]
    return ok


import functools


@functools.lru_cache(maxsize=None)
def _flash_fn(window: Optional[int], q_chunk: int, kv_chunk: int, has_valid: bool):
    """custom_vjp flash attention specialized to static (window, chunks)."""

    def fwd_impl(q, k, v, q_positions, kv_positions, kv_valid):
        b, sqp, hq, d = q.shape
        _, skp, hkv, _ = k.shape
        g = hq // hkv
        nq, nk = sqp // q_chunk, skp // kv_chunk
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
        out_dtype = v.dtype

        qc = q.reshape(b, nq, q_chunk, hkv, g, d)
        qpos = q_positions.reshape(nq, q_chunk)
        kc = k.reshape(b, nk, kv_chunk, hkv, d)
        vc = v.reshape(b, nk, kv_chunk, hkv, d)
        kpos = kv_positions.reshape(nk, kv_chunk)
        valid = kv_valid.reshape(b, nk, kv_chunk) if has_valid else None

        def one_q_chunk(args):
            q_blk, qpos_blk = args  # [b, qc, hkv, g, d], [qc]

            def kv_body(carry, inp):
                m, l, acc = carry
                if valid is None:
                    k_blk, v_blk, kpos_blk = inp
                    valid_blk = None
                else:
                    k_blk, v_blk, kpos_blk, valid_blk = inp
                s = jnp.einsum(
                    "bqhgd,bkhd->bhgqk", q_blk, k_blk,
                    preferred_element_type=jnp.float32,
                ) * scale
                s = jnp.where(
                    _chunk_mask(qpos_blk, kpos_blk, valid_blk, window), s, NEG_INF
                )
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l = l * alpha + jnp.sum(p, axis=-1)
                pv = jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32,
                )
                acc = acc * alpha[..., None] + pv
                return (m_new, l, acc), None

            init = (
                jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, g, q_chunk), jnp.float32),
                jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32),
            )
            xs = (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), kpos)
            if valid is not None:
                xs = xs + (valid.transpose(1, 0, 2),)
            (m, l, acc), _ = jax.lax.scan(kv_body, init, xs)
            out = acc / jnp.maximum(l, 1e-30)[..., None]  # [b,hkv,g,qc,d]
            lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), jnp.inf)
            return out.transpose(0, 3, 1, 2, 4).astype(out_dtype), lse

        outs, lses = jax.lax.map(
            one_q_chunk, (qc.transpose(1, 0, 2, 3, 4, 5), qpos)
        )  # [nq, b, qc, hkv, g, d], [nq, b, hkv, g, qc]
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sqp, hq, d)
        return out, lses  # lse kept chunked: [nq, b, hkv, g, qc]

    def f(q, k, v, q_positions, kv_positions, kv_valid):
        return fwd_impl(q, k, v, q_positions, kv_positions, kv_valid)[0]

    def f_fwd(q, k, v, q_positions, kv_positions, kv_valid):
        out, lse = fwd_impl(q, k, v, q_positions, kv_positions, kv_valid)
        return out, (q, k, v, q_positions, kv_positions, kv_valid, out, lse)

    def f_bwd(res, dout):
        q, k, v, q_positions, kv_positions, kv_valid, out, lse = res
        b, sqp, hq, d = q.shape
        _, skp, hkv, _ = k.shape
        g = hq // hkv
        nq, nk = sqp // q_chunk, skp // kv_chunk
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

        qc = q.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
        doc = dout.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
        outc = out.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
        qpos = q_positions.reshape(nq, q_chunk)
        kc = k.reshape(b, nk, kv_chunk, hkv, d)
        vc = v.reshape(b, nk, kv_chunk, hkv, d)
        kpos = kv_positions.reshape(nk, kv_chunk)
        valid = kv_valid.reshape(b, nk, kv_chunk) if has_valid else None
        # delta_i = rowsum(dout_i * out_i): [nq, b, hkv, g, qc]
        delta = jnp.sum(
            doc.astype(jnp.float32) * outc.astype(jnp.float32), axis=-1
        ).transpose(0, 1, 3, 4, 2)

        def kv_outer(dq_acc, inp_j):
            if valid is None:
                k_blk, v_blk, kpos_blk = inp_j
                valid_blk = None
            else:
                k_blk, v_blk, kpos_blk, valid_blk = inp_j

            def q_inner(carry, inp_i):
                dk_j, dv_j = carry
                q_blk, do_blk, lse_blk, delta_blk, qpos_blk = inp_i
                s = jnp.einsum(
                    "bqhgd,bkhd->bhgqk", q_blk, k_blk,
                    preferred_element_type=jnp.float32,
                ) * scale
                ok = _chunk_mask(qpos_blk, kpos_blk, valid_blk, window)
                # p = exp(s - lse); fully-masked rows have lse=+inf -> p=0.
                p = jnp.where(ok, jnp.exp(s - lse_blk[..., None]), 0.0)
                dv_j = dv_j + jnp.einsum(
                    "bhgqk,bqhgd->bkhd", p, do_blk.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
                dp = jnp.einsum(
                    "bqhgd,bkhd->bhgqk", do_blk, v_blk,
                    preferred_element_type=jnp.float32,
                )
                ds = p * (dp - delta_blk[..., None]) * scale
                dq_blk = jnp.einsum(
                    "bhgqk,bkhd->bqhgd", ds, k_blk.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
                dk_j = dk_j + jnp.einsum(
                    "bhgqk,bqhgd->bkhd", ds, q_blk.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
                return (dk_j, dv_j), dq_blk

            init = (
                jnp.zeros((b, kv_chunk, hkv, d), jnp.float32),
                jnp.zeros((b, kv_chunk, hkv, d), jnp.float32),
            )
            (dk_j, dv_j), dq_js = jax.lax.scan(
                q_inner, init, (qc, doc, lse, delta, qpos)
            )
            return dq_acc + dq_js, (dk_j, dv_j)

        xs = (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), kpos)
        if valid is not None:
            xs = xs + (valid.transpose(1, 0, 2),)
        dq0 = jnp.zeros((nq, b, q_chunk, hkv, g, d), jnp.float32)
        dq_c, (dk_c, dv_c) = jax.lax.scan(kv_outer, dq0, xs)
        dq = dq_c.transpose(1, 0, 2, 3, 4, 5).reshape(b, sqp, hq, d)
        dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(b, skp, hkv, d)
        dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(b, skp, hkv, d)
        return (
            dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None, None,
        )

    flash = jax.custom_vjp(f)
    flash.defvjp(f_fwd, f_bwd)
    return flash



@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Static description of a decode KV cache.

    For sliding-window layers the cache is a rolling buffer of ``window``
    slots (the Mistral/Mixtral rolling cache), which is what makes the
    long_500k decode cell O(window) instead of O(seq).
    """

    batch: int
    n_layers: int
    max_len: int  # slots actually materialized (min(seq, window) for SWA)
    n_kv_heads: int
    d_head: int
    dtype: jnp.dtype = jnp.bfloat16

    def init(self):
        shape = (self.n_layers, self.batch, self.max_len, self.n_kv_heads, self.d_head)
        return {
            "k": jnp.zeros(shape, self.dtype),
            "v": jnp.zeros(shape, self.dtype),
        }

    def abstract(self):
        shape = (self.n_layers, self.batch, self.max_len, self.n_kv_heads, self.d_head)
        return {
            "k": jax.ShapeDtypeStruct(shape, self.dtype),
            "v": jax.ShapeDtypeStruct(shape, self.dtype),
        }


def cache_update(
    cache_k: jax.Array,  # [B, M, Hkv, D] one layer's cache
    cache_v: jax.Array,
    k_new: jax.Array,  # [B, 1, Hkv, D]
    v_new: jax.Array,
    cur_len: jax.Array,  # int32[] tokens already in cache
    rolling: bool,
):
    m = cache_k.shape[1]
    slot = (cur_len % m) if rolling else jnp.minimum(cur_len, m - 1)
    ck = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0))
    return ck, cv


def decode_attention(
    q: jax.Array,  # [B, 1, Hq, D] current-token queries (RoPE applied)
    cache_k: jax.Array,  # [B, M, Hkv, D] already containing the new token
    cache_v: jax.Array,
    cur_len: jax.Array,  # int32[] position of the CURRENT token
    *,
    window: Optional[int] = None,
    impl: str = "xla",
) -> jax.Array:
    """One-token attention against the cache.

    Cache slot i holds absolute position i for dense caches, or position
    ``i + floor((cur_len - i) / M) * M``-style wrap for rolling caches; we
    reconstruct absolute positions from cur_len for masking.
    """
    b, m = cache_k.shape[0], cache_k.shape[1]
    slots = jnp.arange(m, dtype=jnp.int32)
    if window is None:
        kv_pos = slots  # direct-mapped cache
        valid = slots <= cur_len
    else:
        # Rolling buffer: slot s currently holds the largest position p <=
        # cur_len with p % M == s.
        cur_slot = cur_len % m
        wrapped = slots > cur_slot
        kv_pos = cur_len - cur_slot + slots - jnp.where(wrapped, m, 0)
        valid = (kv_pos >= 0) & (kv_pos > cur_len - window) & (kv_pos <= cur_len)
    q_pos = cur_len[None].astype(jnp.int32)
    out = gqa_attention(
        q,
        cache_k,
        cache_v,
        q_positions=q_pos,
        kv_positions=kv_pos,
        kv_valid=jnp.broadcast_to(valid[None], (b, m)),
        window=None,  # windowing already folded into `valid`
        impl=impl,
    )
    return out
