"""Two-tower retrieval model (YouTube/RecSys'19 style): huge sparse embedding
tables -> towers -> dot interaction -> in-batch sampled softmax with logQ
correction.

JAX has no native EmbeddingBag: ``embedding_bag`` below builds it from
``jnp.take`` + ``jax.ops.segment_sum`` (ragged path) or masked mean (fixed-
width path) — this is part of the system, not a stub.  Tables are
column-sharded over the ``tp`` axis (each device holds all rows, 1/16 of the
embedding dim), so lookups stay local and the backward scatter-add stays
local; row-sharding alternatives are explored in §Perf.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import mlp, mlp_init
from repro.sharding.rules import shard


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_users: int = 8_388_608  # 2^23
    n_items: int = 2_097_152  # 2^21
    embed_dim: int = 256
    tower_dims: Tuple[int, ...] = (1024, 512, 256)
    hist_len: int = 32
    temperature: float = 0.05
    compute_dtype: jnp.dtype = jnp.bfloat16


TWO_TOWER_PARAM_RULES = [
    (r"(user|item)_table", ("fsdp", "tp")),
    (r"(user|item)_tower/layer\d+/w", ("fsdp", "tp")),
    (r".*/b", (None,)),
]


def init_params(key, cfg: TwoTowerConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.embed_dim
    return {
        "user_table": 0.02 * jax.random.normal(k1, (cfg.n_users, d), jnp.float32),
        "item_table": 0.02 * jax.random.normal(k2, (cfg.n_items, d), jnp.float32),
        "user_tower": mlp_init(k3, [2 * d, *cfg.tower_dims]),
        "item_tower": mlp_init(k4, [d, *cfg.tower_dims]),
    }


def abstract_params(cfg: TwoTowerConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


# ----------------------------- EmbeddingBag ---------------------------------


def embedding_bag_ragged(
    table: jax.Array,  # [V, D]
    flat_ids: jax.Array,  # int32[T] concatenated bag members
    bag_ids: jax.Array,  # int32[T] which bag each member belongs to
    n_bags: int,
    mode: str = "mean",
    weights: Optional[jax.Array] = None,
) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent: ragged gather + segment reduce."""
    rows = jnp.take(table, flat_ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
        cnt = jax.ops.segment_sum(
            jnp.ones_like(flat_ids, rows.dtype), bag_ids, num_segments=n_bags
        )
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, bag_ids, num_segments=n_bags)
    raise ValueError(mode)


def embedding_bag_padded(
    table: jax.Array, ids: jax.Array, mask: jax.Array, mode: str = "mean"
) -> jax.Array:
    """Fixed-width bags: ids [B, H], mask [B, H] -> [B, D]."""
    rows = jnp.take(table, ids, axis=0)  # [B, H, D]
    m = mask.astype(rows.dtype)[..., None]
    if mode == "sum":
        return (rows * m).sum(1)
    if mode == "mean":
        return (rows * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
    raise ValueError(mode)


# ------------------------------- towers -------------------------------------


def user_embedding(params, cfg: TwoTowerConfig, user_id, hist, hist_mask):
    cd = cfg.compute_dtype
    ue = jnp.take(params["user_table"], user_id, axis=0)  # [B, D]
    hb = embedding_bag_padded(params["item_table"], hist, hist_mask, "mean")
    z = jnp.concatenate([ue, hb], axis=-1).astype(cd)
    z = shard(z, "batch", None)
    u = mlp(params["user_tower"], z, act=jax.nn.relu, compute_dtype=cd)
    u = u.astype(jnp.float32)
    return u / (jnp.linalg.norm(u, axis=-1, keepdims=True) + 1e-6)


def item_embedding(params, cfg: TwoTowerConfig, item_id):
    cd = cfg.compute_dtype
    z = jnp.take(params["item_table"], item_id, axis=0).astype(cd)
    z = shard(z, "batch", None)
    v = mlp(params["item_tower"], z, act=jax.nn.relu, compute_dtype=cd)
    v = v.astype(jnp.float32)
    return v / (jnp.linalg.norm(v, axis=-1, keepdims=True) + 1e-6)


def loss_in_batch_softmax(params, cfg: TwoTowerConfig, batch):
    """Sampled softmax over in-batch negatives with logQ correction."""
    u = user_embedding(params, cfg, batch["user_id"], batch["hist"], batch["hist_mask"])
    v = item_embedding(params, cfg, batch["item_id"])
    logits = (u @ v.T) / cfg.temperature  # [B, B]
    logits = shard(logits, "batch", "vocab")
    logits = logits - batch["logq"][None, :]  # logQ correction
    b = logits.shape[0]
    labels = jnp.arange(b, dtype=jnp.int32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - ll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "in_batch_acc": acc}


def serve_scores(params, cfg: TwoTowerConfig, batch):
    """Online/offline pairwise scoring: one score per (user, item) row."""
    u = user_embedding(params, cfg, batch["user_id"], batch["hist"], batch["hist_mask"])
    v = item_embedding(params, cfg, batch["item_id"])
    return jnp.sum(u * v, axis=-1) / cfg.temperature


def retrieval_topk(params, cfg: TwoTowerConfig, batch, k: int = 100):
    """One query scored against a large candidate set: batched matmul + top_k
    (NOT a loop), as the retrieval_cand shape requires."""
    u = user_embedding(
        params, cfg, batch["user_id"], batch["hist"], batch["hist_mask"]
    )  # [1, D]
    v = item_embedding(params, cfg, batch["cand_ids"])  # [Ncand, D]
    v = shard(v, "vocab", None)
    scores = (u @ v.T)[0] / cfg.temperature  # [Ncand]
    return jax.lax.top_k(scores, k)
