"""Shared neural-net building blocks (hand-rolled: no flax/optax offline).

Params are plain nested dicts of jax.Arrays; initializers take an explicit
key.  Sharding is attached afterwards from path-pattern rules (see
sharding/rules.py), so these modules stay mesh-agnostic.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def trunc_normal(key, shape, std, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(
        dtype
    )


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, std: Optional[float] = None, dtype=jnp.float32):
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    p = {"w": trunc_normal(key, (d_in, d_out), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, compute_dtype=None):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def mlp_init(key, dims: Sequence[int], *, bias: bool = True, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"layer{i}": dense_init(keys[i], dims[i], dims[i + 1], bias=bias, dtype=dtype)
        for i in range(len(dims) - 1)
    }


def mlp(p, x, act=jax.nn.silu, compute_dtype=None, final_act: bool = False):
    n = len(p)
    for i in range(n):
        x = dense(p[f"layer{i}"], x, compute_dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ----------------------------- RoPE ----------------------------------------


def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, d_head]; positions: [..., seq] int32."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)  # [d/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, d/2]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., seq, 1, d/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------- losses & metrics -----------------------------------


def softmax_cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None,
    z_loss: float = 0.0,
):
    """Token-level CE with optional z-loss; logits promoted to f32.

    logits: [..., V]; labels int32 [...]; mask broadcastable to labels.
    Returns (mean loss, dict of aux metrics).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(loss)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    mean = (loss * mask).sum() / denom
    acc = ((jnp.argmax(logits, -1) == labels) * mask).sum() / denom
    return mean, {"loss": mean, "accuracy": acc, "tokens": denom}


def l2_loss(pred: jax.Array, target: jax.Array):
    err = (pred.astype(jnp.float32) - target.astype(jnp.float32)) ** 2
    loss = jnp.mean(err)
    return loss, {"loss": loss, "rmse": jnp.sqrt(loss)}


# --------------------- segment ops (GNN substrate) ---------------------------


def segment_mean(data, segment_ids, num_segments):
    s = jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)
    cnt = jax.ops.segment_sum(
        jnp.ones(data.shape[:1], data.dtype), segment_ids, num_segments=num_segments
    )
    return s / jnp.maximum(cnt, 1.0)[(...,) + (None,) * (data.ndim - 1)]


def segment_softmax(scores, segment_ids, num_segments):
    """Softmax over variable-size segments (edge softmax)."""
    smax = jax.ops.segment_max(scores, segment_ids, num_segments=num_segments)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    ex = jnp.exp(scores - smax[segment_ids])
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments=num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-9)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
