"""Visitor core of the invariant linter: findings, rules, suppressions.

The model:

  * a :class:`Rule` is one contract checker — it declares a stable ``id``,
    a severity, a path scope (:meth:`Rule.applies`) and a :meth:`Rule.check`
    that yields :class:`Finding`s from a parsed :class:`SourceFile`;
  * the registry (:data:`RULES`, filled by the :func:`register` decorator
    when ``repro.analysis.rules`` is imported) is the single source of
    truth for rule ids — docs/analysis.md is cross-checked against it by
    ``scripts/check_docs.py``;
  * inline suppressions use ``# repro: allow(<rule>) <justification>`` —
    trailing a line it covers that line, on a line of its own it covers
    the next line.  The runner (:func:`analyze_file`) applies them and
    then lints the suppressions themselves: a missing justification or an
    unknown rule id is a ``bad-suppression`` finding (and does NOT
    suppress), a suppression that matched nothing is ``unused-suppression``
    — so exemptions can never silently accumulate.

No jax imports anywhere in this package: the linter must run in a bare
CPython (the CI gating job and check_docs import it without the
accelerator stack).
"""

from __future__ import annotations

import ast
import dataclasses
import io
import os
import tokenize
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "META_RULES",
    "RULES",
    "Finding",
    "Rule",
    "SourceFile",
    "Suppression",
    "all_rules",
    "analyze_file",
    "analyze_paths",
    "dotted",
    "register",
    "render_finding",
]


# ---------------------------------------------------------------------------
# Findings
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation: file:line anchor, rule id, message, fix-it hint."""

    rule: str
    path: str  # repo-relative (or as given) — the display path
    line: int
    col: int
    message: str
    hint: str = ""
    severity: str = "error"  # "error" | "warn"


def render_finding(f: Finding) -> str:
    out = f"{f.path}:{f.line}:{f.col}: [{f.rule}] {f.severity}: {f.message}"
    if f.hint:
        out += f"\n    hint: {f.hint}"
    return out


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

# Meta rules the runner itself emits; they exist in the registry surface
# (docs table, --list-rules) but have no checker class and cannot be
# suppressed — the linter lints its own exemption mechanism.
META_RULES = {
    "bad-suppression": (
        "a `# repro: allow(...)` comment must name a known rule and carry "
        "a justification"
    ),
    "unused-suppression": (
        "a `# repro: allow(...)` comment that suppresses nothing must be "
        "removed (stale exemptions hide future violations)"
    ),
}

_ALLOW_PREFIX = "repro:"
_ALLOW_KEYWORD = "allow("


@dataclasses.dataclass
class Suppression:
    """One parsed ``# repro: allow(rule[, rule...]) justification``."""

    rules: Tuple[str, ...]
    justification: str
    comment_line: int  # where the comment sits
    covers_line: int  # the line findings are matched against
    col: int
    used: bool = False
    malformed: str = ""  # non-empty -> bad-suppression message


def _parse_suppressions(text: str) -> List[Suppression]:
    """Tokenize-based scan (comments only — the allow() syntax appearing in
    a string literal is inert, which tests/fixtures pin)."""
    sups: List[Suppression] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return sups
    lines = text.splitlines()
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        body = tok.string.lstrip("#").strip()
        if not body.startswith(_ALLOW_PREFIX):
            continue
        body = body[len(_ALLOW_PREFIX):].strip()
        line, col = tok.start
        standalone = lines[line - 1][: col].strip() == ""
        covers = line + 1 if standalone else line
        if not body.startswith(_ALLOW_KEYWORD) or ")" not in body:
            sups.append(
                Suppression(
                    rules=(),
                    justification="",
                    comment_line=line,
                    covers_line=covers,
                    col=col,
                    malformed=(
                        "malformed suppression: expected "
                        "`# repro: allow(<rule>) <justification>`"
                    ),
                )
            )
            continue
        inside, _, rest = body[len(_ALLOW_KEYWORD):].partition(")")
        rules = tuple(r.strip() for r in inside.split(",") if r.strip())
        sups.append(
            Suppression(
                rules=rules,
                justification=rest.strip(),
                comment_line=line,
                covers_line=covers,
                col=col,
            )
        )
    return sups


# ---------------------------------------------------------------------------
# Source model
# ---------------------------------------------------------------------------


class SourceFile:
    """One parsed file: text, AST, suppressions, and its scope flag.

    ``scoped`` is True when the file was reached by walking the library
    tree (rules apply their own path scoping) and False when it was given
    explicitly (fixture mode: every rule checks fully, path-independent —
    how tests/test_analysis.py drives the known-bad corpus)."""

    def __init__(self, path: str, rel: str, text: str, scoped: bool = True):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.text = text
        self.scoped = scoped
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(text)
        except SyntaxError as e:  # surfaced as a finding by the runner
            self.parse_error = e
        self.suppressions = _parse_suppressions(text)

    @classmethod
    def read(cls, path: str, rel: Optional[str] = None, scoped: bool = True):
        with open(path, encoding="utf-8") as f:
            text = f.read()
        return cls(path, rel if rel is not None else path, text, scoped)

    def in_scope(self, *prefixes: str) -> bool:
        """True when this file is inside one of ``prefixes`` — or when the
        file is being checked unscoped (fixture mode)."""
        if not self.scoped:
            return True
        return any(
            self.rel == p or self.rel.startswith(p.rstrip("/") + "/")
            for p in prefixes
        )


# ---------------------------------------------------------------------------
# Rules + registry
# ---------------------------------------------------------------------------


class Rule:
    """Base checker.  Subclasses set ``id``/``summary``/``contract`` and
    implement :meth:`check`; :meth:`applies` scopes the rule to the library
    paths whose contract it encodes (bypassed entirely in fixture mode)."""

    id: str = ""
    summary: str = ""  # one line, shown by --list-rules and the docs table
    severity: str = "error"

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/")

    def check(self, sf: SourceFile, project) -> Iterator[Finding]:
        raise NotImplementedError

    # -- helpers for subclasses --------------------------------------------
    def finding(
        self, sf: SourceFile, node: ast.AST, message: str, hint: str = ""
    ) -> Finding:
        return Finding(
            rule=self.id,
            path=sf.rel,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            message=message,
            hint=hint,
            severity=self.severity,
        )


RULES: Dict[str, Rule] = {}


def register(cls):
    """Class decorator: instantiates and registers a Rule by its id."""
    inst = cls()
    if not inst.id:
        raise ValueError(f"{cls.__name__} has no rule id")
    if inst.id in RULES or inst.id in META_RULES:
        raise ValueError(f"duplicate rule id {inst.id!r}")
    RULES[inst.id] = inst
    return cls


def all_rules() -> Dict[str, str]:
    """Every known rule id -> one-line summary (checkers + meta rules) —
    the surface docs/analysis.md is synced against."""
    out = {rid: r.summary for rid, r in sorted(RULES.items())}
    out.update(sorted(META_RULES.items()))
    return out


# ---------------------------------------------------------------------------
# Shared AST helpers
# ---------------------------------------------------------------------------


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------


def _meta_findings(sf: SourceFile) -> List[Finding]:
    out: List[Finding] = []
    known = set(RULES) | set(META_RULES)
    for sup in sf.suppressions:
        if sup.malformed:
            out.append(
                Finding(
                    "bad-suppression", sf.rel, sup.comment_line, sup.col,
                    sup.malformed,
                    hint="# repro: allow(<rule>) <justification>",
                )
            )
            continue
        bad = False
        for rid in sup.rules:
            if rid in META_RULES:
                out.append(
                    Finding(
                        "bad-suppression", sf.rel, sup.comment_line, sup.col,
                        f"meta rule {rid!r} cannot be suppressed",
                        hint="fix or remove the underlying suppression",
                    )
                )
                bad = True
            elif rid not in known:
                out.append(
                    Finding(
                        "bad-suppression", sf.rel, sup.comment_line, sup.col,
                        f"unknown rule {rid!r} in suppression",
                        hint=f"known rules: {', '.join(sorted(known))}",
                    )
                )
                bad = True
        if not sup.rules:
            out.append(
                Finding(
                    "bad-suppression", sf.rel, sup.comment_line, sup.col,
                    "suppression names no rule",
                    hint="# repro: allow(<rule>) <justification>",
                )
            )
            bad = True
        if not sup.justification:
            out.append(
                Finding(
                    "bad-suppression", sf.rel, sup.comment_line, sup.col,
                    "suppression has no justification",
                    hint=(
                        "say WHY the violation is deliberate: "
                        "# repro: allow(<rule>) <justification>"
                    ),
                )
            )
            bad = True
        if not bad and not sup.used:
            out.append(
                Finding(
                    "unused-suppression", sf.rel, sup.comment_line, sup.col,
                    f"suppression for {', '.join(sup.rules)} matched no finding",
                    hint="remove it (stale exemptions hide future violations)",
                )
            )
    return out


def _valid(sup: Suppression) -> bool:
    """Only well-formed, justified suppressions actually suppress."""
    return (
        not sup.malformed
        and bool(sup.rules)
        and bool(sup.justification)
        and not any(r in META_RULES for r in sup.rules)
    )


def analyze_file(
    path: str,
    rel: Optional[str] = None,
    rules: Optional[Sequence[Rule]] = None,
    project=None,
    scoped: bool = True,
) -> List[Finding]:
    """Runs the rule set over one file, applies suppressions, lints them."""
    from repro.analysis.project import Project

    sf = SourceFile.read(path, rel=rel, scoped=scoped)
    if sf.parse_error is not None:
        e = sf.parse_error
        return [
            Finding(
                "syntax-error", sf.rel, e.lineno or 1, e.offset or 0,
                f"file does not parse: {e.msg}",
            )
        ]
    if project is None:
        project = Project.load()
    active = list(rules) if rules is not None else list(RULES.values())
    raw: List[Finding] = []
    for rule in active:
        if scoped and not rule.applies(sf.rel):
            continue
        raw.extend(rule.check(sf, project))
    kept: List[Finding] = []
    for f in raw:
        sup = next(
            (
                s
                for s in sf.suppressions
                if _valid(s) and s.covers_line == f.line and f.rule in s.rules
            ),
            None,
        )
        if sup is not None:
            sup.used = True
        else:
            kept.append(f)
    kept.extend(_meta_findings(sf))
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept


def _walk_py(root_path: str) -> Iterator[str]:
    for dirpath, dirnames, filenames in os.walk(root_path):
        dirnames[:] = sorted(
            d for d in dirnames if d not in ("__pycache__", ".git")
        )
        for name in sorted(filenames):
            if name.endswith(".py"):
                yield os.path.join(dirpath, name)


def analyze_paths(
    paths: Iterable[str],
    root: Optional[str] = None,
    rules: Optional[Sequence[Rule]] = None,
    project=None,
    scoped: bool = True,
) -> List[Finding]:
    """Analyzes files and/or directory trees.  ``root`` anchors the
    repo-relative display paths (default: cwd); explicitly listed FILES are
    always analyzed, directories are walked for ``*.py``."""
    root = os.path.abspath(root or os.getcwd())
    out: List[Finding] = []
    for p in paths:
        ap = os.path.abspath(p if os.path.isabs(p) else os.path.join(root, p))
        targets = [ap] if os.path.isfile(ap) else list(_walk_py(ap))
        for t in targets:
            rel = os.path.relpath(t, root)
            out.extend(
                analyze_file(
                    t, rel=rel, rules=rules, project=project, scoped=scoped
                )
            )
    return out
