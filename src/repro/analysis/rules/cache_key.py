"""cache-key: exempt Problem fields stay out of traced programs.

Contract (PR 5's program cache, hardened here): ``Solver`` reuses one
compiled program across every ``Problem`` that differs only in
key-EXEMPT fields (``api._FIELD_CLASS`` marks them ``"exempt"`` —
execution-strategy knobs like ``stream_chunk`` or ``cache_dir``).  If a
traced program builder reads an exempt field, two Problems that map to
the SAME cache key produce DIFFERENT programs — whichever compiled first
silently serves both.  Conversely, every new ``Problem`` field must be
classified in ``_FIELD_CLASS`` at all (static / conditional / exempt) or
the cache-key derivation has an undeclared input.

Checks:

  * inside a traced def (see ``analysis.tracing``) or a
    ``_build_*_program`` builder, no attribute read of a key-exempt
    field name;
  * in any file defining both ``class Problem`` and ``_FIELD_CLASS``:
    the dataclass fields and the classification keys must match exactly,
    and every classification must be one of static/conditional/exempt.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import Finding, Rule, SourceFile, register
from repro.analysis.tracing import collect_traced_scopes

_CLASSES = ("static", "conditional", "exempt")
_FuncDef = (ast.FunctionDef, ast.AsyncFunctionDef)


def _is_builder(node: ast.AST) -> bool:
    return (
        isinstance(node, _FuncDef)
        and node.name.startswith("_build_")
        and node.name.endswith("_program")
    )


def _own_field_class(tree: ast.Module):
    """This module's _FIELD_CLASS literal (fixtures carry their own)."""
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "_FIELD_CLASS":
                    if isinstance(node.value, ast.Dict):
                        return node.value, node
    return None, None


def _own_problem_fields(tree: ast.Module):
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == "Problem":
            return [
                (stmt.target.id, stmt)
                for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
            ], node
    return None, None


@register
class CacheKeyRule(Rule):
    id = "cache-key"
    summary = (
        "key-exempt Problem fields are never read inside traced program "
        "builders, and every Problem field is classified in _FIELD_CLASS"
    )

    def check(self, sf: SourceFile, project) -> Iterator[Finding]:
        # -- exempt-field reads in traced/builder code ----------------------
        exempt = set(project.exempt_fields)
        if exempt:
            scopes = collect_traced_scopes(sf.tree)
            hot = set(scopes.defs)
            for node in ast.walk(sf.tree):
                if _is_builder(node):
                    hot.add(node)
            seen = set()
            for d in hot:
                for sub in ast.walk(d):
                    if (
                        isinstance(sub, ast.Attribute)
                        and sub.attr in exempt
                        and id(sub) not in seen
                    ):
                        seen.add(id(sub))
                        yield self.finding(
                            sf,
                            sub,
                            f"key-exempt Problem field {sub.attr!r} read "
                            "inside a traced program builder — two Problems "
                            "with the same cache key would compile different "
                            "programs",
                            hint=(
                                "thread the value in as a runtime argument, "
                                "or reclassify the field in api._FIELD_CLASS "
                                "(which widens the cache key)"
                            ),
                        )

        # -- Problem fields <-> _FIELD_CLASS sync ---------------------------
        fc, fc_node = _own_field_class(sf.tree)
        fields, cls_node = _own_problem_fields(sf.tree)
        if fc is None or fields is None:
            return
        classified = {}
        for k, v in zip(fc.keys, fc.values):
            if isinstance(k, ast.Constant) and isinstance(k.value, str):
                val = v.value if isinstance(v, ast.Constant) else None
                classified[k.value] = (val, k)
        for fname, stmt in fields:
            if fname not in classified:
                yield self.finding(
                    sf,
                    stmt,
                    f"Problem field {fname!r} is not classified in "
                    "_FIELD_CLASS — the cache key has an undeclared input",
                    hint=(
                        "add it to _FIELD_CLASS as static, conditional, or "
                        "exempt (exempt fields are excluded from _key)"
                    ),
                )
        field_names = {f for f, _ in fields}
        for cname, (cval, knode) in classified.items():
            if cname not in field_names:
                yield self.finding(
                    sf,
                    knode,
                    f"_FIELD_CLASS entry {cname!r} matches no Problem field",
                    hint="remove the stale entry or fix the field name",
                )
            if cval not in _CLASSES:
                yield self.finding(
                    sf,
                    knode,
                    f"_FIELD_CLASS[{cname!r}] = {cval!r} is not one of "
                    f"{'/'.join(_CLASSES)}",
                    hint="classify as static, conditional, or exempt",
                )
