"""atomic-io: persistent artifacts are published via ioutil.atomic_write_file.

Contract (PR 4's spill manifest, PR 5's program cache, PR 8's checkpoint
hardening — see CHANGES.md): anything a later process may READ BACK —
checkpoints, cache entries, spill manifests, reports — is written with
the tmp + fsync + ``os.replace`` dance that ``ioutil.atomic_write_file``
owns, so a crash at any byte leaves the old artifact or the new one,
never a torn hybrid.  The fault-injection suite (tests/test_resilience.py)
only proves crash-safety for writes routed through that one primitive; a
raw ``open(path, "w")`` is unprotected by construction.

The checker flags, outside ``src/repro/ioutil.py``:

  * ``open``/``os.fdopen`` with a write-capable constant mode
    (``w``/``a``/``x``/``+``);
  * ``os.replace`` / ``os.rename`` (the publish step belongs to ioutil);
  * ``os.fsync`` (durability belongs to ioutil);
  * ``Path.write_text`` / ``Path.write_bytes``.

Deliberate exceptions (append-only data files whose manifest is published
last, directory-level two-phase commits) carry inline
``# repro: allow(atomic-io) <why this publish is already crash-safe>``.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.core import Finding, Rule, SourceFile, dotted, register

_IOUTIL_REL = "src/repro/ioutil.py"
_HINT = (
    "publish through repro.ioutil.atomic_write_file (tmp + fsync + "
    "os.replace) so a crash leaves the old artifact or the new one"
)


def _write_mode(call: ast.Call) -> Optional[str]:
    """The constant mode string of an open()-style call iff write-capable."""
    mode_node: Optional[ast.expr] = None
    if len(call.args) >= 2:
        mode_node = call.args[1]
    for kw in call.keywords:
        if kw.arg == "mode":
            mode_node = kw.value
    if mode_node is None:
        return None
    if not (isinstance(mode_node, ast.Constant) and isinstance(mode_node.value, str)):
        return None
    mode = mode_node.value
    return mode if any(c in mode for c in "wax+") else None


@register
class AtomicIoRule(Rule):
    id = "atomic-io"
    summary = (
        "persistent artifacts are written only via ioutil.atomic_write_file "
        "— no raw write-mode open/os.replace/fsync in the library"
    )

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/") and rel != _IOUTIL_REL

    def check(self, sf: SourceFile, project) -> Iterator[Finding]:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func)
            if name in ("open", "io.open", "os.fdopen"):
                mode = _write_mode(node)
                if mode is not None:
                    yield self.finding(
                        sf,
                        node,
                        f"raw {name}(..., {mode!r}) write of a persistent "
                        "artifact",
                        hint=_HINT,
                    )
            elif name in ("os.replace", "os.rename"):
                yield self.finding(
                    sf,
                    node,
                    f"{name} outside ioutil — the atomic publish step is "
                    "atomic_write_file's job",
                    hint=_HINT,
                )
            elif name == "os.fsync":
                yield self.finding(
                    sf,
                    node,
                    "os.fsync outside ioutil — durability is "
                    "atomic_write_file's job",
                    hint=_HINT,
                )
            elif isinstance(node.func, ast.Attribute) and node.func.attr in (
                "write_text",
                "write_bytes",
            ):
                yield self.finding(
                    sf,
                    node,
                    f"Path.{node.func.attr} bypasses the atomic-publish "
                    "primitive",
                    hint=_HINT,
                )
