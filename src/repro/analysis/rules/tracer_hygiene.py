"""tracer-hygiene: traced code never round-trips through the host.

Contract (PRs 2-7 accumulated traced program builders in api/turnstile/
serve): inside a jitted or shard_mapped body, a Python ``if``/``while``
on a traced value raises TracerBoolConversionError at best and silently
forces a host sync at worst; ``int()``/``float()``/``bool()``/``np.*``
on a traced value materialize it to the host, defeating the async
dispatch pipeline; ``.block_until_ready()``/``.item()``/``.tolist()``/
``jax.device_get`` are explicit sync points that belong at the driver
boundary, never inside library traced code.

Detection is scoped to defs the tracer actually enters (see
``analysis.tracing``: decorator-jitted, name-passed to
jit/shard_map/vmap/pmap, or nested inside those).  ``static_argnames``
are honored — branching on a static arg is host control flow by
construction.  Host-side drivers that legitimately sync (serve engine's
sampling loop, checkpoint host transfer) are outside traced defs and are
never flagged.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from repro.analysis.core import Finding, Rule, SourceFile, dotted, register
from repro.analysis.tracing import collect_traced_scopes

_FuncDef = (ast.FunctionDef, ast.AsyncFunctionDef)
_SYNC_ATTRS = ("block_until_ready", "item", "tolist")
_CASTS = ("int", "float", "bool")


def _param_names(fn) -> Set[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return {n for n in names if n != "self"}


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _is_noneness_test(test: ast.expr) -> bool:
    """``x is None`` / ``x is not None`` — host-decidable, never flagged."""
    return isinstance(test, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops
    )


def _walk_own(fn) -> Iterator[ast.AST]:
    """Nodes of ``fn``'s body excluding nested defs (those are visited as
    their own traced scopes)."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, _FuncDef):
                stack.append(child)


@register
class TracerHygieneRule(Rule):
    id = "tracer-hygiene"
    summary = (
        "no host round-trips inside traced code: no Python branches on "
        "traced values, no int()/np.* casts, no block_until_ready/item"
    )

    def check(self, sf: SourceFile, project) -> Iterator[Finding]:
        scopes = collect_traced_scopes(sf.tree)
        for fn, statics in scopes.defs.items():
            dynamic = _param_names(fn) - statics
            for node in _walk_own(fn):
                if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                    test = node.test
                    if _is_noneness_test(test):
                        continue
                    touched = _names_in(test) & dynamic
                    if touched:
                        kind = "while" if isinstance(node, ast.While) else "if"
                        yield self.finding(
                            sf,
                            node,
                            f"Python `{kind}` on traced value(s) "
                            f"{', '.join(sorted(touched))} inside a traced "
                            "def — host control flow forces a sync (or "
                            "raises under jit)",
                            hint=(
                                "use jnp.where / lax.cond / lax.while_loop, "
                                "or declare the argument in static_argnames"
                            ),
                        )
                elif isinstance(node, ast.Call):
                    name = dotted(node.func)
                    if name in _CASTS and any(
                        _names_in(a) & dynamic for a in node.args
                    ):
                        yield self.finding(
                            sf,
                            node,
                            f"host cast {name}() of a traced value inside a "
                            "traced def",
                            hint=(
                                "keep it on device (astype / jnp ops); cast "
                                "at the driver boundary after the program "
                                "returns"
                            ),
                        )
                    elif name is not None and name.split(".", 1)[0] in (
                        "np",
                        "numpy",
                    ):
                        if any(_names_in(a) & dynamic for a in node.args):
                            yield self.finding(
                                sf,
                                node,
                                f"host numpy call {name}() on a traced value "
                                "inside a traced def — device→host transfer",
                                hint="use the jnp equivalent",
                            )
                    elif name in ("jax.device_get", "device_get"):
                        yield self.finding(
                            sf,
                            node,
                            "jax.device_get inside a traced def — explicit "
                            "device→host transfer",
                            hint="transfers belong at the driver boundary",
                        )
                    elif isinstance(
                        node.func, ast.Attribute
                    ) and node.func.attr in _SYNC_ATTRS:
                        yield self.finding(
                            sf,
                            node,
                            f".{node.func.attr}() inside a traced def — "
                            "host sync point",
                            hint=(
                                "sync at the driver boundary; traced code "
                                "stays async"
                            ),
                        )
