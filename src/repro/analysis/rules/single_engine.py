"""single-engine: the peel threshold exists once, in core/engine.py.

Contract (PR 1, re-stated in engine.py's module docstring): the paper's
removal threshold ``2(1+eps)·rho`` is computed by
:func:`repro.core.engine.removal_threshold` and nowhere else.  Every
wrapper — streaming driver, mesh ladder, turnstile maintenance, serving
fallbacks — calls the engine; none re-derives the expression.  A re-typed
threshold is how the single-engine architecture silently forks: the two
copies drift the day one of them is tuned.

The checker flags, outside ``src/repro/core/engine.py``:

  * the expression pattern ``2 * (1 + <eps>)`` (any numeric spelling,
    either operand order, any name containing ``eps``);
  * a function definition named ``removal_threshold`` (a shadow of the
    engine's one threshold site).
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import Finding, Rule, SourceFile, dotted, register

_ENGINE_REL = "src/repro/core/engine.py"


def _is_const(node: ast.AST, value: float) -> bool:
    return (
        isinstance(node, ast.Constant)
        and isinstance(node.value, (int, float))
        and not isinstance(node.value, bool)
        and float(node.value) == value
    )


def _mentions_eps(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        name = dotted(sub)
        if name is not None and "eps" in name.rsplit(".", 1)[-1].lower():
            return True
    return False


def _is_one_plus_eps(node: ast.AST) -> bool:
    if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add)):
        return False
    l, r = node.left, node.right
    return (_is_const(l, 1.0) and _mentions_eps(r)) or (
        _is_const(r, 1.0) and _mentions_eps(l)
    )


def _is_threshold_expr(node: ast.AST) -> bool:
    """``2 * (1 + eps)`` in either operand order."""
    if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult)):
        return False
    l, r = node.left, node.right
    return (_is_const(l, 2.0) and _is_one_plus_eps(r)) or (
        _is_const(r, 2.0) and _is_one_plus_eps(l)
    )


@register
class SingleEngineRule(Rule):
    id = "single-engine"
    summary = (
        "the 2(1+eps)·rho removal threshold is computed only by "
        "core/engine.py:removal_threshold — no re-derived peel thresholds"
    )

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/") and rel != _ENGINE_REL

    def check(self, sf: SourceFile, project) -> Iterator[Finding]:
        for node in ast.walk(sf.tree):
            if _is_threshold_expr(node):
                yield self.finding(
                    sf,
                    node,
                    "re-derived peel threshold `2 * (1 + eps)` outside the "
                    "engine",
                    hint=(
                        "call repro.core.engine.removal_threshold(eps, rho) "
                        "— the expression exists once, in "
                        + _ENGINE_REL
                    ),
                )
            elif (
                isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name == "removal_threshold"
            ):
                yield self.finding(
                    sf,
                    node,
                    "shadow definition of removal_threshold outside the "
                    "engine",
                    hint=(
                        "import it: from repro.core.engine import "
                        "removal_threshold"
                    ),
                )
