"""fault-sites: the fault-injection surface is a closed, documented registry.

Contract (PR 8's resilience runtime): chaos tests steer injection by SITE
NAME, so the set of names is an API — ``faults.KNOWN_SITES`` is its
registry and docs/resilience.md its documentation.  Two failure shapes
this rule closes off:

  * a ``faults.fire("typo.site")`` call whose name is not registered —
    chaos plans targeting the registry would silently never hit it;
  * an except-wrapped IO path in the failure-contract modules (streaming,
    progcache, spill, turnstile, serve) WITHOUT a hook — recovery code the
    fault suite cannot reach, i.e. untested-by-construction error
    handling.

The second check is structural: a ``try`` whose body performs file IO and
that catches exceptions must also call ``faults.fire(...)`` inside the
``try`` body (the hook sits before the IO it makes injectable).
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import Finding, Rule, SourceFile, dotted, register

# Modules bound by the failure contract (check 2 applies only here when
# walking the tree; fixture files are checked unconditionally).
_FAILURE_SCOPES = (
    "src/repro/core/streaming.py",
    "src/repro/core/progcache.py",
    "src/repro/core/turnstile.py",
    "src/repro/graph/edgelist.py",
    "src/repro/serve/",
    "src/repro/checkpoint/",
)

_IO_CALLS = frozenset(
    {
        "open",
        "io.open",
        "os.fdopen",
        "os.replace",
        "os.rename",
        "os.fsync",
        "os.makedirs",
        "atomic_write_file",
        "np.load",
        "np.save",
        "np.savez",
        "pickle.load",
        "pickle.loads",
        "pickle.dump",
        "pickle.dumps",
        "json.load",
        "json.dump",
        "shutil.rmtree",
    }
)


def _is_fire(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = dotted(node.func)
    return name is not None and (
        name == "fire" or name.endswith(".fire")
    )


def _does_io(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    name = dotted(node.func)
    if name is None:
        return False
    return name in _IO_CALLS or name.rsplit(".", 1)[-1] == "atomic_write_file"


@register
class FaultSitesRule(Rule):
    id = "fault-sites"
    summary = (
        "fire() sites come from faults.KNOWN_SITES, and every except-wrapped "
        "IO path in the failure-contract modules carries an injection hook"
    )

    def check(self, sf: SourceFile, project) -> Iterator[Finding]:
        known = set(project.known_sites)
        for node in ast.walk(sf.tree):
            # 1. literal site names must be registered
            if _is_fire(node) and node.args:
                site = node.args[0]
                if (
                    isinstance(site, ast.Constant)
                    and isinstance(site.value, str)
                    and site.value not in known
                ):
                    yield self.finding(
                        sf,
                        node,
                        f"fire() site {site.value!r} is not registered in "
                        "faults.KNOWN_SITES",
                        hint=(
                            "add it to faults.KNOWN_SITES and document it in "
                            "docs/resilience.md's fault-site table"
                        ),
                    )
            # 2. except-wrapped IO without a hook (failure-contract modules)
            if (
                isinstance(node, ast.Try)
                and node.handlers
                and sf.in_scope(*_FAILURE_SCOPES)
            ):
                body_nodes = [n for stmt in node.body for n in ast.walk(stmt)]
                if any(_does_io(n) for n in body_nodes) and not any(
                    _is_fire(n) for n in body_nodes
                ):
                    yield self.finding(
                        sf,
                        node,
                        "except-wrapped IO path without a faults.fire() hook "
                        "— this recovery branch is unreachable by the chaos "
                        "suite",
                        hint=(
                            "call faults.fire('<module>.<site>') at the top "
                            "of the try body (and register the site)"
                        ),
                    )
