"""The checker suite.  Importing this package registers every rule.

One module per contract; each module's docstring states the contract it
encodes and the PR history that motivated it (docs/analysis.md renders
the same table for humans).
"""

from repro.analysis.rules import (  # noqa: F401  (registration imports)
    atomic_io,
    cache_key,
    fault_sites,
    pow2_constants,
    single_engine,
    tracer_hygiene,
)
