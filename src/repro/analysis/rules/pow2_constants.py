"""pow2-constants: bucket floors and capacities come from repro/constants.py.

Contract (PR 3's compaction ladder + every runtime since): recompilation
count is governed by pow2 bucketing — a padded capacity is snapped to a
power of two above a FLOOR so nearby sizes share one compiled program.
Those floors are load-bearing: the retrace-budget smoke and the pinned
``trace_count`` assertions in the test suite encode them.  A re-typed
literal floor (``pow2_bucket(n, 64)``) forks the constant; the day
``constants.py`` is tuned the forked site silently keeps the old value
and the retrace budget splits.

The checker flags, outside ``src/repro/constants.py``:

  * a literal int passed as the ``floor``/``stride`` argument of
    ``pow2_bucket``/``ladder_schedule`` (pass ``constants.X`` or a module
    alias ``_X = constants.X`` instead);
  * a module-level assignment of a capacity-suffixed name (``*_FLOOR``,
    ``*_MIN_EDGES``, ``*_MIN_NODES``, ``*_MAX_SEGMENTS``, ``*_STRIDE``)
    to a literal int — alias the constants surface instead (aliases stay
    monkeypatch-able for tests; the value has one home).
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.analysis.core import Finding, Rule, SourceFile, dotted, register

_CONSTANTS_REL = "src/repro/constants.py"
_CAPACITY_SUFFIXES = (
    "_FLOOR",
    "_MIN_EDGES",
    "_MIN_NODES",
    "_MAX_SEGMENTS",
    "_STRIDE",
)
# callable name -> (positional index, keyword name) of its capacity args
_CAPACITY_ARGS = {
    "pow2_bucket": ((1, "floor"),),
    "ladder_schedule": ((1, "floor"), (2, "stride")),
}


def _literal_int(node: Optional[ast.expr]) -> Optional[int]:
    if (
        node is not None
        and isinstance(node, ast.Constant)
        and isinstance(node.value, int)
        and not isinstance(node.value, bool)
    ):
        return node.value
    return None


@register
class Pow2ConstantsRule(Rule):
    id = "pow2-constants"
    summary = (
        "pow2 bucket floors / ladder capacities come from repro/constants.py "
        "— no literal floors at call sites, no re-typed capacity constants"
    )

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/") and rel != _CONSTANTS_REL

    def check(self, sf: SourceFile, project) -> Iterator[Finding]:
        surface = ", ".join(sorted(project.capacity_constants)) or "(none)"
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                name = dotted(node.func)
                base = name.rsplit(".", 1)[-1] if name else None
                for pos, kw in _CAPACITY_ARGS.get(base, ()):
                    arg = None
                    if len(node.args) > pos:
                        arg = node.args[pos]
                    for k in node.keywords:
                        if k.arg == kw:
                            arg = k.value
                    val = _literal_int(arg)
                    if val is not None:
                        yield self.finding(
                            sf,
                            arg,
                            f"literal {kw}={val} passed to {base}() — the "
                            "capacity is forked from the constants surface",
                            hint=(
                                "pass a repro.constants name (surface: "
                                f"{surface})"
                            ),
                        )
        # module-level re-typed capacity constants
        for node in sf.tree.body:
            if not isinstance(node, ast.Assign):
                continue
            val = _literal_int(node.value)
            if val is None:
                continue
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id.upper().endswith(
                    _CAPACITY_SUFFIXES
                ):
                    yield self.finding(
                        sf,
                        node,
                        f"capacity constant {t.id} = {val} re-typed outside "
                        "the constants surface",
                        hint=(
                            f"alias it: {t.id} = constants.<NAME> (add the "
                            "value to src/repro/constants.py if it is new)"
                        ),
                    )
