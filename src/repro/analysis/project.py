"""Static views of the repo's contract surfaces.

The rules need three facts about THIS repo: the registered fault sites
(``faults.KNOWN_SITES``), the cache-key classification of ``Problem``
fields (``api._FIELD_CLASS`` + the dataclass itself), and the named
capacity constants (``repro/constants.py``).  All three are extracted by
PARSING the source — never importing it — so the linter stays jax-free
and sees the tree exactly as committed (an import-time rewrite could not
hide a violation from it).
"""

from __future__ import annotations

import ast
import os
from typing import Dict, Optional, Tuple

__all__ = ["Project"]

_FAULTS_REL = "src/repro/faults.py"
_API_REL = "src/repro/core/api.py"
_CONSTANTS_REL = "src/repro/constants.py"


def _repo_root() -> str:
    # src/repro/analysis/project.py -> repo root is four levels up.
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.abspath(os.path.join(here, "..", "..", ".."))


def _parse(path: str) -> Optional[ast.Module]:
    if not os.path.isfile(path):
        return None
    with open(path, encoding="utf-8") as f:
        try:
            return ast.parse(f.read())
        except SyntaxError:
            return None


def _module_assign(tree: Optional[ast.Module], name: str) -> Optional[ast.expr]:
    if tree is None:
        return None
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return node.value
    return None


class Project:
    """Lazily-parsed contract surfaces, shared across all analyzed files.

    ``Project.load()`` anchors at the repo this package lives in — the
    normal case for both the CLI and the fixture tests (fixtures trip the
    rules against the REAL registries).  Tests can also construct one with
    an explicit root to analyze a synthetic tree."""

    def __init__(self, root: str):
        self.root = root
        self._known_sites: Optional[Tuple[str, ...]] = None
        self._field_class: Optional[Dict[str, str]] = None
        self._problem_fields: Optional[Tuple[str, ...]] = None
        self._constants: Optional[Dict[str, int]] = None

    _DEFAULT: Optional["Project"] = None

    @classmethod
    def load(cls, root: Optional[str] = None) -> "Project":
        if root is not None:
            return cls(os.path.abspath(root))
        if cls._DEFAULT is None:
            cls._DEFAULT = cls(_repo_root())
        return cls._DEFAULT

    # -- fault sites --------------------------------------------------------
    @property
    def known_sites(self) -> Tuple[str, ...]:
        """``faults.KNOWN_SITES`` parsed from source (empty if absent)."""
        if self._known_sites is None:
            val = _module_assign(
                _parse(os.path.join(self.root, _FAULTS_REL)), "KNOWN_SITES"
            )
            sites = []
            if isinstance(val, (ast.Tuple, ast.List)):
                for el in val.elts:
                    if isinstance(el, ast.Constant) and isinstance(el.value, str):
                        sites.append(el.value)
            self._known_sites = tuple(sites)
        return self._known_sites

    # -- Problem cache-key classification -----------------------------------
    @property
    def field_class(self) -> Dict[str, str]:
        """``api._FIELD_CLASS`` parsed from source: field -> class."""
        if self._field_class is None:
            val = _module_assign(
                _parse(os.path.join(self.root, _API_REL)), "_FIELD_CLASS"
            )
            out: Dict[str, str] = {}
            if isinstance(val, ast.Dict):
                for k, v in zip(val.keys, val.values):
                    if (
                        isinstance(k, ast.Constant)
                        and isinstance(k.value, str)
                        and isinstance(v, ast.Constant)
                        and isinstance(v.value, str)
                    ):
                        out[k.value] = v.value
            self._field_class = out
        return self._field_class

    @property
    def exempt_fields(self) -> Tuple[str, ...]:
        return tuple(
            sorted(f for f, c in self.field_class.items() if c == "exempt")
        )

    @property
    def problem_fields(self) -> Tuple[str, ...]:
        """Annotated field names of the ``Problem`` dataclass."""
        if self._problem_fields is None:
            tree = _parse(os.path.join(self.root, _API_REL))
            fields = []
            if tree is not None:
                for node in tree.body:
                    if isinstance(node, ast.ClassDef) and node.name == "Problem":
                        for stmt in node.body:
                            if isinstance(stmt, ast.AnnAssign) and isinstance(
                                stmt.target, ast.Name
                            ):
                                fields.append(stmt.target.id)
            self._problem_fields = tuple(fields)
        return self._problem_fields

    # -- pow2/padding constants ---------------------------------------------
    @property
    def capacity_constants(self) -> Dict[str, int]:
        """Module-level integer constants of ``repro/constants.py``."""
        if self._constants is None:
            tree = _parse(os.path.join(self.root, _CONSTANTS_REL))
            out: Dict[str, int] = {}
            if tree is not None:
                for node in tree.body:
                    if isinstance(node, ast.Assign) and isinstance(
                        node.value, ast.Constant
                    ):
                        for t in node.targets:
                            if isinstance(t, ast.Name) and isinstance(
                                node.value.value, int
                            ):
                                out[t.id] = node.value.value
            self._constants = out
        return self._constants
