"""Static detection of TRACED code regions (jit / shard_map / vmap bodies).

The tracer-hygiene and cache-key rules both need to know which function
bodies execute under a jax trace.  Exactly-decidable in general it is not;
this module pins the repo's actual idioms, which cover every traced
program builder in the tree:

  * a def decorated ``@jax.jit`` / ``@jit`` / ``@partial(jax.jit, ...)``
    (``functools.partial`` spelled out included);
  * a def (or method) whose NAME is passed to ``jax.jit(...)``,
    ``jit(...)``, ``shard_map(...)``, ``jax.vmap(...)`` / ``vmap(...)``
    or ``pmap`` anywhere in the same module (``jax.jit(fn)``,
    ``shard_map(_ladder, mesh=...)``, ``jax.jit(self._decode_impl)``);
  * every def lexically nested inside a traced def.

Functions merely CALLED from traced code (e.g. ``run_cell`` or the engine
pass bodies) are NOT marked — that boundary keeps the rule's false-positive
rate at zero on host-side helpers, and the retrace-budget CI smoke
(scripts/retrace_smoke.py) backstops what slips past the static net.

``static_argnames`` declared on the jit call/decorator are honored: a
Python ``if`` on a static argument is host control flow by construction
and never flagged.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import dotted

__all__ = ["TracedScopes", "collect_traced_scopes"]

_FuncDef = (ast.FunctionDef, ast.AsyncFunctionDef)

# Callables whose function argument is traced when invoked.
_TRACING_ENTRY_SUFFIXES = ("jit", "shard_map", "vmap", "pmap")


def _is_tracing_entry(func: ast.expr) -> bool:
    name = dotted(func)
    if name is None:
        return False
    last = name.rsplit(".", 1)[-1]
    return last in _TRACING_ENTRY_SUFFIXES


def _static_argnames(call: ast.Call) -> Set[str]:
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            vals = (
                kw.value.elts
                if isinstance(kw.value, (ast.Tuple, ast.List))
                else [kw.value]
            )
            for v in vals:
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    out.add(v.value)
    return out


def _decorator_trace_info(dec: ast.expr) -> Optional[Set[str]]:
    """None if the decorator doesn't trace; else its static_argnames."""
    if _is_tracing_entry(dec):
        return set()
    if isinstance(dec, ast.Call):
        # @partial(jax.jit, static_argnames=...) / @jax.jit(...)
        if _is_tracing_entry(dec.func):
            return _static_argnames(dec)
        fname = dotted(dec.func)
        if fname is not None and fname.rsplit(".", 1)[-1] == "partial":
            if dec.args and _is_tracing_entry(dec.args[0]):
                return _static_argnames(dec)
    return None


class TracedScopes:
    """The set of traced function defs of one module, with per-def static
    argument names."""

    def __init__(self):
        self.defs: Dict[ast.AST, Set[str]] = {}  # traced def -> static args
        self._parents: Dict[ast.AST, Optional[ast.AST]] = {}

    def is_traced(self, node: ast.AST) -> bool:
        return node in self.defs

    def enclosing(self, chain: List[ast.AST]) -> Optional[Tuple[ast.AST, Set[str]]]:
        """Innermost traced def in a lexical def chain (outer..inner)."""
        for d in reversed(chain):
            if d in self.defs:
                return d, self.defs[d]
        return None


def collect_traced_scopes(tree: ast.Module) -> TracedScopes:
    scopes = TracedScopes()

    # Pass 1: all defs by name (module functions AND methods share the map:
    # `jax.jit(self._decode_impl)` marks the method by its attr name).
    by_name: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, _FuncDef):
            by_name.setdefault(node.name, []).append(node)

    # Pass 2: decorator-marked defs.
    for node in ast.walk(tree):
        if isinstance(node, _FuncDef):
            for dec in node.decorator_list:
                statics = _decorator_trace_info(dec)
                if statics is not None:
                    scopes.defs[node] = statics

    # Pass 3: defs whose name is passed to a tracing entry point.
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _is_tracing_entry(node.func)):
            continue
        if not node.args:
            continue
        target = node.args[0]
        name = None
        if isinstance(target, ast.Name):
            name = target.id
        elif isinstance(target, ast.Attribute):
            name = target.attr  # jax.jit(self._decode_impl)
        if name is None:
            continue
        statics = _static_argnames(node)
        for d in by_name.get(name, []):
            scopes.defs[d] = scopes.defs.get(d, set()) | statics

    # Pass 4: defs nested inside traced defs inherit the traced scope (and
    # the parent's static names — a closure over a static arg stays static).
    for d in list(scopes.defs):
        statics = scopes.defs[d]
        for child in ast.walk(d):
            if child is not d and isinstance(child, _FuncDef):
                scopes.defs.setdefault(child, set(statics))
    return scopes
