"""repro.analysis — the AST-based invariant linter for this repo.

Eight PRs of growth left the runtime's correctness resting on
written-down-but-unenforced contracts: the removal threshold exists only
in ``engine.removal_threshold``, persistent artifacts are published only
through ``ioutil.atomic_write_file``, every failure-prone IO site threads
a ``faults.fire()`` hook, cache-key-exempt ``Problem`` fields never leak
into traced programs, traced code never round-trips through the host, and
pow2 floors/capacities live on one constants surface.  Each rule here
encodes one of those contracts as a mechanical AST check, so the machine —
not the reviewer — holds the line (docs/analysis.md has the rule table and
the CHANGES.md history each rule came from).

Front door::

    PYTHONPATH=src python scripts/analyze.py [--strict] [paths...]

or programmatically::

    from repro.analysis import analyze_paths
    findings = analyze_paths(["src/repro"], root=REPO)

Inline suppressions (`# repro: allow(<rule>) <justification>`) are parsed
per file; a suppression without a justification, naming an unknown rule,
or matching no finding is itself a finding (the suppressions are linted
too).  This package is deliberately jax-free — pure ``ast``/stdlib — so
the gating CI job and ``scripts/check_docs.py`` can import it without the
accelerator stack.
"""

from repro.analysis.core import (
    META_RULES,
    Finding,
    Rule,
    RULES,
    SourceFile,
    Suppression,
    all_rules,
    analyze_file,
    analyze_paths,
    register,
    render_finding,
)
from repro.analysis.project import Project

# Importing the rules package registers every checker.
from repro.analysis import rules as _rules  # noqa: F401  (registration side effect)

__all__ = [
    "META_RULES",
    "RULES",
    "Finding",
    "Project",
    "Rule",
    "SourceFile",
    "Suppression",
    "all_rules",
    "analyze_file",
    "analyze_paths",
    "register",
    "render_finding",
]
