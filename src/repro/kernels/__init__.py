# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from __future__ import annotations

from typing import Optional

import jax


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """THE pallas dispatch rule, shared by every kernel wrapper: ``None``
    means "compiled on TPU, interpreter elsewhere".  A hard ``interpret=True``
    default used to run kernels through the (orders of magnitude slower)
    interpreter even on TPU because no public wrapper ever flipped it."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
