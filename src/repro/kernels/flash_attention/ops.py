"""Public fused-attention op: Pallas forward (VMEM-resident score tiles) +
the validated XLA flash backward from models/attention.py, glued with a
custom VJP.  Interface-compatible with ``gqa_attention(..., impl='pallas')``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd_pallas


def _to_flat_heads(q, k, v):
    """[B,S,Hq,D]/[B,S,Hkv,D] -> ([B*Hq,S,D], [B*Hq,Sk,D], ...) expanding KV
    per group (gather, not materialized repeat, under XLA CSE)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kx = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * hq, -1, d)
    vx = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * hq, -1, d)
    return qf, kx, vx


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Sk, Hkv, D]
    v: jax.Array,
    *,
    q_positions: jax.Array,  # int32[Sq]
    kv_positions: jax.Array,  # int32[Sk]
    kv_valid: Optional[jax.Array] = None,
    window: Optional[int] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    if kv_valid is not None:
        raise NotImplementedError(
            "pallas path is for full-sequence attention; decode w/ cache "
            "validity uses the XLA path"
        )
    b, sq, hq, d = q.shape
    sk = k.shape[1]

    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_kv
    qp = jnp.pad(q_positions, (0, pad_q), constant_values=-(2**30))[None]
    kp = jnp.pad(kv_positions, (0, pad_k), constant_values=2**30)[None]
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qf, kf, vf = _to_flat_heads(q, k, v)
    out = flash_attention_fwd_pallas(
        qf, kf, vf, qp, kp,
        window=window, block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    out = out.reshape(b, hq, sq + pad_q, d).transpose(0, 2, 1, 3)
    return out[:, :sq]


def flash_attention_trainable(
    q, k, v, *, q_positions, kv_positions, window=None,
    block_q: int = 128, block_kv: int = 128, interpret: bool = True,
    bwd_q_chunk: int = 512, bwd_kv_chunk: int = 1024,
):
    """Pallas forward + XLA flash backward via custom VJP (training path)."""
    from repro.models.attention import _chunked_gqa

    @jax.custom_vjp
    def f(q, k, v):
        return flash_attention(
            q, k, v, q_positions=q_positions, kv_positions=kv_positions,
            window=window, block_q=block_q, block_kv=block_kv,
            interpret=interpret,
        )

    def f_fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def f_bwd(res, dout):
        q, k, v = res

        def xla_fwd(q, k, v):
            return _chunked_gqa(
                q, k, v, q_positions=q_positions, kv_positions=kv_positions,
                kv_valid=None, window=window,
                q_chunk=bwd_q_chunk, kv_chunk=bwd_kv_chunk,
            )

        _, vjp = jax.vjp(xla_fwd, q, k, v)
        return vjp(dout)

    f.defvjp(f_fwd, f_bwd)
    return f(q, k, v)
