"""Pallas TPU kernel: fused causal (+sliding-window) GQA flash attention.

VMEM schedule (FlashAttention-2 style, adapted to the TPU grid):

  grid = (batch x q_heads, n_q_blocks, n_kv_blocks)   [kv innermost]

  * q block [Bq, D] loaded once per (head, q-block), resident across the kv
    dimension; k/v blocks [Bk, D] stream through VMEM;
  * running (m, l, acc) live in VMEM scratch across the kv grid dim,
    finalized (acc / l) into the output block on the LAST kv step —
    HBM traffic is exactly q + k + v + out (+ positions), never the S^2
    score matrix: this is what removes the memory-bound term the XLA
    chunked path pays at 32k prefill;
  * causal + window masks are computed from position blocks with iota
    compares; fully-masked (q,kv) block pairs still occupy grid steps on
    TPU (no dynamic skip) — the win from skipping is modeled in
    EXPERIMENTS.md Perf, implemented via the window-clipped kv range below.

GQA: kv head index = q head index // (Hq // Hkv), folded into the index
maps, so KV stays in its grouped layout (no repeat, unlike the XLA path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _flash_kernel(
    qpos_ref, kpos_ref, q_ref, k_ref, v_ref, out_ref,
    m_ref, l_ref, acc_ref,
    *, window, scale,
):
    kb = pl.program_id(2)
    n_kb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, :]  # [Bq, D]
    k = k_ref[0, :, :]  # [Bk, D]
    v = v_ref[0, :, :]
    qpos = qpos_ref[0, :]  # int32[Bq]
    kpos = kpos_ref[0, :]  # int32[Bk]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [Bq, Bk]
    ok = kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(kb == n_kb - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)
        out_ref[0, :, :] = out.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_kv", "interpret"),
)
def flash_attention_fwd_pallas(
    q: jax.Array,  # [BH, Sq, D]  (batch*heads flattened)
    k: jax.Array,  # [BH, Sk, D]  (kv head already selected per q head)
    v: jax.Array,  # [BH, Sk, D]
    q_positions: jax.Array,  # int32[1, Sq]
    kv_positions: jax.Array,  # int32[1, Sk]
    *,
    window,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_kv == 0
    scale = 1.0 / (d ** 0.5)

    grid = (bh, sq // block_q, sk // block_kv)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, window=window, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, i, j: (0, i)),
            pl.BlockSpec((1, block_kv), lambda b, i, j: (0, j)),
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu_scratch((block_q, 1), jnp.float32),
            pltpu_scratch((block_q, 1), jnp.float32),
            pltpu_scratch((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, kv_positions, q, k, v)
    return out


def pltpu_scratch(shape, dtype):
    """VMEM scratch allocation (portable across pallas backends)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
