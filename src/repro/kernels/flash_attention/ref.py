"""Pure-jnp oracle for the flash kernel: dense masked softmax attention on
the flattened [BH, S, D] layout the kernel consumes."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, q_positions, kv_positions, *, window):
    """q,k,v: [BH, S, D]; positions: [1, S] int32."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    qp, kp = q_positions[0], kv_positions[0]
    ok = kp[None, :] <= qp[:, None]
    if window is not None:
        ok &= kp[None, :] > qp[:, None] - window
    s = jnp.where(ok[None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", (p / jnp.maximum(l, 1e-30)).astype(v.dtype), v)
    return out.astype(q.dtype)
