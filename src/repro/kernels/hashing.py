"""The ONE multiply-shift hash family (uint32, int32-safe).

Every sketch in the system hashes node ids (Count-Sketch §5.1) or edge
id pairs (the ℓ0-sampling sketch of the turnstile runtime) with the same
Dietzfelbinger-style wrap-around multiply-shift mix: odd uint32 multiplier,
uint32 offset, mod-2^32 arithmetic, xorshift finalizer.  This module is the
single spelling of that family — ``core/countsketch.py`` and
``kernels/l0_sampler/`` both delegate here, and the Pallas kernels inline
the SAME functions (they are plain ``jnp`` uint32 ops, traceable inside
``pallas_call``), so host references, jit programs and TPU kernels agree
bit for bit.

Everything is int32-safe: no value ever needs x64, overflow is the
mod-2^32 wrap the family is built on (both XLA and numpy wrap uint32
array arithmetic silently).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "AVALANCHE",
    "bucket32",
    "mix32",
    "mix32_pair",
    "sign32",
]

# Odd avalanche multiplier for the pair mix's second round (the level /
# fingerprint hashes read HIGH bits of a two-term sum, which a single
# multiply-shift round leaves too linear in (x, y)).
AVALANCHE = 0x7FEB352D


def mix32(a, c, x):
    """Wrap-around multiply-shift mix of one key: ``h = a*x + c`` (mod
    2^32), xorshift-finalized.  ``a`` must be odd.  All operands uint32
    (broadcasting is the caller's concern)."""
    h = a * x + c
    return h ^ (h >> 16)


def bucket32(h, n_buckets: int):
    """int32 bucket index from a mixed uint32 (the Count-Sketch table
    column rule: low bits after the finalizer)."""
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def sign32(h):
    """±1.0 float32 sign from a mixed uint32's top bit (the Count-Sketch
    g_i rule)."""
    return jnp.where((h >> 31) == 0, 1.0, -1.0).astype(jnp.float32)


def mix32_pair(a_x, a_y, c, x, y):
    """Wrap-around mix of a key PAIR: ``h = a_x*x + a_y*y + c`` (mod 2^32)
    with a two-round finalizer (xorshift, odd avalanche multiply,
    xorshift).  The ℓ0 sampler hashes undirected edges ``(u, v)`` with
    this — no 64-bit edge id is ever formed, so the family stays
    int32-safe for any node count that fits int32.  Both multipliers must
    be odd; the extra rounds decorrelate the HIGH bits (the geometric
    level assignment reads them) from the linear structure of the sum."""
    h = a_x * x + a_y * y + c
    h = h ^ (h >> 16)
    h = h * jnp.uint32(AVALANCHE)
    return h ^ (h >> 15)
