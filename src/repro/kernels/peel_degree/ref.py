"""Pure-jnp oracle for the tiled degree kernel: plain segment_sum over the
same tiled layout (bit-exact target, modulo f32 summation order)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tiled_degrees_ref(
    target_local: jax.Array,  # int32[n_tiles, max_epT], -1 padding
    w: jax.Array,  # float32[n_tiles, max_epT]
    *,
    tile_size: int,
) -> jax.Array:
    """float32[n_tiles, tile_size] via per-tile segment_sum."""
    n_tiles = target_local.shape[0]

    def per_tile(tl, wt):
        safe = jnp.where(tl >= 0, tl, tile_size)  # padding -> overflow bucket
        return jax.ops.segment_sum(wt, safe, num_segments=tile_size + 1)[:-1]

    return jax.vmap(per_tile)(target_local, w)


def degrees_from_tiled(deg_tiles: jax.Array, n_nodes: int) -> jax.Array:
    """[n_tiles, tile_size] -> [n_nodes] (drops tile padding)."""
    return deg_tiles.reshape(-1)[:n_nodes]
