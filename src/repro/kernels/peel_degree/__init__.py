from repro.kernels.peel_degree.ops import tiled_degrees
