"""Pallas TPU kernel: per-pass degree histogram over tile-bucketed edges.

The paper's per-pass hot spot is the reduce-side degree count.  TPUs have no
efficient data-dependent scatter, so the scatter becomes MXU work:

  * edges were bucketed by target-node TILE once (graph/partition.py — the
    'shuffle', done one time, not per pass);
  * each grid step loads one (tile, edge-block) pair into VMEM, builds the
    one-hot matrix ``onehot[e, t] = (target_local[e] == t)`` with iota +
    compare (a VPU op), and accumulates ``w[1, E_blk] @ onehot[E_blk, T]``
    into the tile's degree row — a [1, E] x [E, T] matmul on the MXU;
  * the degree row stays resident in VMEM across the edge-block grid
    dimension (output BlockSpec index ignores it), so HBM sees each degree
    tile exactly once.

Grid: (n_tiles, n_edge_blocks).  VMEM per step: E_blk ints + E_blk floats +
E_blk x T onehot + 8 x T accumulator — for the default (E_blk=512, T=1024)
that is ~2.2 MB, comfortably inside the ~16 MB less double-buffering budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _degree_kernel(tl_ref, w_ref, out_ref):
    """One (tile, edge-block) grid step.

    tl_ref:  int32[1, E_blk]      target ids local to this tile (-1 = padding)
    w_ref:   float32[1, E_blk]    current alive-weight of each slot (0 = dead)
    out_ref: float32[1, 8, T]     this tile's degree row (8 sublanes for MXU)
    """
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tl = tl_ref[0, :]
    w = w_ref[0, :]
    t = out_ref.shape[2]
    # one-hot via iota compare; padding (-1) matches no column.
    cols = jax.lax.broadcasted_iota(jnp.int32, (tl.shape[0], t), 1)
    onehot = (tl[:, None] == cols).astype(jnp.float32)
    # [1, E_blk] @ [E_blk, T] on the MXU.
    partial = jnp.dot(
        w[None, :], onehot, preferred_element_type=jnp.float32
    )  # [1, T]
    out_ref[0, 0:1, :] += partial


@functools.partial(
    jax.jit, static_argnames=("tile_size", "block_e", "interpret")
)
def tiled_degrees_pallas(
    target_local: jax.Array,  # int32[n_tiles, max_epT]
    w: jax.Array,  # float32[n_tiles, max_epT] per-slot alive weight
    *,
    tile_size: int,
    block_e: int = 512,
    interpret: bool | None = None,  # None: compiled on TPU, interpreter elsewhere
) -> jax.Array:
    """Returns float32[n_tiles, tile_size] degree histogram."""
    from repro.kernels import resolve_interpret

    interpret = resolve_interpret(interpret)
    n_tiles, max_epT = target_local.shape
    assert max_epT % block_e == 0, (max_epT, block_e)
    n_eb = max_epT // block_e

    out = pl.pallas_call(
        _degree_kernel,
        grid=(n_tiles, n_eb),
        in_specs=[
            pl.BlockSpec((1, block_e), lambda t, e: (t, e)),
            pl.BlockSpec((1, block_e), lambda t, e: (t, e)),
        ],
        out_specs=pl.BlockSpec((1, 8, tile_size), lambda t, e: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, 8, tile_size), jnp.float32),
        interpret=interpret,
    )(target_local, w)
    return out[:, 0, :]
