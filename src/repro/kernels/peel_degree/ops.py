"""jit'd public wrapper: degrees of the current alive subgraph from the
static tile bucketing.  Drop-in ``degree_fn`` for core/peel.py, so the
Pallas kernel powers the same Algorithm 1 loop the XLA path uses."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.graph.edgelist import EdgeList
from repro.graph.partition import TiledEdges, bucket_edges_by_tile
from repro.kernels import resolve_interpret
from repro.kernels.peel_degree.kernel import tiled_degrees_pallas
from repro.kernels.peel_degree.ref import degrees_from_tiled, tiled_degrees_ref


@partial(jax.jit, static_argnames=("tile_size", "n_nodes", "use_pallas", "interpret"))
def tiled_degrees(
    target_local: jax.Array,  # int32[n_tiles, max_epT]
    edge_index: jax.Array,  # int32[n_tiles, max_epT], -1 padding
    w_alive: jax.Array,  # float32[E] per-ORIGINAL-edge alive weight
    *,
    tile_size: int,
    n_nodes: int,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """float32[n_nodes] degrees of the alive subgraph."""
    interpret = resolve_interpret(interpret)
    # Route each slot's current weight through the static bucketing.
    safe_idx = jnp.maximum(edge_index, 0)
    w = jnp.where(edge_index >= 0, w_alive[safe_idx], 0.0)
    if use_pallas:
        max_epT = target_local.shape[1]
        block_e = next(
            b for b in (512, 256, 128, 64, max_epT) if max_epT % b == 0
        )
        deg_tiles = tiled_degrees_pallas(
            target_local, w, tile_size=tile_size, block_e=block_e,
            interpret=interpret,
        )
    else:
        deg_tiles = tiled_degrees_ref(target_local, w, tile_size=tile_size)
    return degrees_from_tiled(deg_tiles, n_nodes)


def degree_fn_from_tiling(
    tiled: TiledEdges,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
):
    """Builds a ``degree_fn(edges, w_alive)`` hook for core.peel."""
    tl = jnp.asarray(tiled.target_local)
    ei = jnp.asarray(tiled.edge_index)

    def fn(edges: EdgeList, w_alive: jax.Array) -> jax.Array:
        return tiled_degrees(
            tl, ei, w_alive,
            tile_size=tiled.tile_size, n_nodes=tiled.n_nodes,
            use_pallas=use_pallas, interpret=interpret,
        )

    return fn


def degree_backend_from_tiling(
    tiled: TiledEdges,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
):
    """Engine ``DegreeBackend`` wrapping the Pallas tiled-degree kernel, for
    use with :func:`repro.core.engine.run_peel` (undirected policies)."""
    from repro.core.engine import FnBackend

    return FnBackend(
        degree_fn_from_tiling(tiled, use_pallas=use_pallas, interpret=interpret)
    )


def tiling_for_edges(
    edges: EdgeList,
    tile_size: int = 1024,
    block: int = 512,
    pow2_pad: bool = False,
):
    """Buckets ALL edge slots (padding included): ``edge_index`` must address
    the original edge array because the per-pass ``w_alive`` is indexed over
    it, and padded slots already carry weight 0.  ``pow2_pad`` bounds the
    shape set across compaction rungs (see bucket_edges_by_tile)."""
    import numpy as np

    return bucket_edges_by_tile(
        np.asarray(edges.src), np.asarray(edges.dst),
        edges.n_nodes, tile_size=tile_size, block=block,
        directed=False, pow2_pad=pow2_pad,
    )
