"""Pure-jnp oracle for the ℓ0-sampler update: one segment_sum over the
flattened (level, table, cell) space.  This IS the CPU fast path (the
dispatch rule only picks the Pallas kernel on TPU), not just a test
oracle, so it stays jit-friendly: fixed shapes in, one fused scatter out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.l0_sampler.ops import (
    L0Params,
    edge_cells,
    edge_fingerprint,
    edge_level,
)


def l0_delta_ref(
    u: jax.Array,  # int32[E] canonical min endpoint
    v: jax.Array,  # int32[E] canonical max endpoint
    sgn: jax.Array,  # int32[E] ±1 / 0
    params: L0Params,
) -> jax.Array:
    """Sketch delta int32[L, d, C, 4] (wrap-around int32 sums)."""
    L, d, C = params.n_levels, params.n_tables, params.n_cells
    lvl = edge_level(params, u, v)  # [E]
    cells = edge_cells(params, u, v)  # [d, E]
    fp = jax.lax.bitcast_convert_type(edge_fingerprint(params, u, v), jnp.int32)
    flat = (
        lvl[None, :] * (d * C) + jnp.arange(d, dtype=jnp.int32)[:, None] * C + cells
    )  # [d, E]
    vals = jnp.stack([sgn, sgn * u, sgn * v, sgn * fp], axis=-1)  # [E, 4]
    vals_d = jnp.broadcast_to(vals[None], (d,) + vals.shape).reshape(-1, 4)
    delta = jax.ops.segment_sum(vals_d, flat.reshape(-1), num_segments=L * d * C)
    return delta.reshape(L, d, C, 4)
