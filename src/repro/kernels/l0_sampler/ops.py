"""ℓ0-sampling sketch over the undirected edge universe (MTVV, arXiv
1506.04417): geometric-level subsampling + per-cell 1-sparse recovery.

The sketch state is one int32 tensor ``[L, d, C, 4]``:

* ``L`` geometric levels — edge e lands at ``level(e) = min(clz(h(e)), L-1)``
  for a uint32 pair hash ``h``, so level l holds each edge independently
  with probability ``2^-l`` (level 0 holds EVERYTHING: summing levels
  ``>= l`` — a suffix sum, itself linear — is a Bernoulli(2^-l) sample of
  the live edge set, and ``l = 0`` degenerates to exact recovery whenever
  the graph fits the decoder budget).
* ``d`` hash tables of ``C`` cells each (IBLT-style, d=3 default) so the
  host decoder can peel 1-sparse cells.
* 4 int32 fields per cell: ``(count, sum_u, sum_v, fingerprint)``.  All
  arithmetic is wrap-around mod 2^32 (int32 adds), hence every field is
  LINEAR in the update stream: insert = +1 row, delete = -1 row,
  ``sketch(A) + sketch(B) == sketch(A ∪ B)`` exactly, and an
  insert-then-delete leaves all-zeros.  The fingerprint is a second pair
  hash folded in with the same ±1 sign; a cell is a decodable singleton
  iff ``count == 1`` and the fingerprint re-hashes consistently.

Level assignment is COMPARE-BASED — ``level = Σ_{l=1}^{L-1} [h < 2^(32-l)]``
— rather than ``clz`` so the Pallas kernel and the jnp reference share the
exact arithmetic (no dependence on ``lax.clz`` lowering).

Edges are canonicalized to ``u = min < v = max`` before hashing;
self-loops and padding rows get sign 0 and vanish from every field.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import hashing

__all__ = [
    "L0Params",
    "canonicalize_edges",
    "edge_cells",
    "edge_fingerprint",
    "edge_level",
    "l0_delta",
    "l0_sketch_shape",
    "l0_update",
    "make_l0_params",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class L0Params:
    """Hash parameters for an L-level, d-table, C-cell ℓ0 sketch.

    Pair hashes take ``(a_x, a_y, c)`` triples (odd multipliers); the
    level and fingerprint hashes are single triples, the cell hash keeps
    one triple per table.  Two sketches are mergeable iff their params
    (and static shape) match — same seed through
    :func:`make_l0_params` guarantees that.
    """

    a_lvl: jax.Array  # uint32[2] odd multipliers for the level hash
    c_lvl: jax.Array  # uint32[1] offset
    a_fp: jax.Array  # uint32[2] odd multipliers for the fingerprint hash
    c_fp: jax.Array  # uint32[1] offset
    a_cell: jax.Array  # uint32[d, 2] odd multipliers for the cell hashes
    c_cell: jax.Array  # uint32[d] offsets
    n_levels: int = dataclasses.field(metadata=dict(static=True))
    n_cells: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_tables(self) -> int:
        return self.a_cell.shape[0]


def make_l0_params(
    n_levels: int = 32, n_cells: int = 1 << 14, n_tables: int = 3, seed: int = 0
) -> L0Params:
    rng = np.random.default_rng(seed)
    odd = lambda *s: (rng.integers(0, 1 << 31, size=s, dtype=np.int64) * 2 + 1).astype(
        np.uint32
    )
    any32 = lambda *s: rng.integers(0, 1 << 32, size=s, dtype=np.int64).astype(np.uint32)
    return L0Params(
        a_lvl=jnp.asarray(odd(2)),
        c_lvl=jnp.asarray(any32(1)),
        a_fp=jnp.asarray(odd(2)),
        c_fp=jnp.asarray(any32(1)),
        a_cell=jnp.asarray(odd(n_tables, 2)),
        c_cell=jnp.asarray(any32(n_tables)),
        n_levels=int(n_levels),
        n_cells=int(n_cells),
    )


def l0_sketch_shape(p: L0Params) -> tuple:
    return (p.n_levels, p.n_tables, p.n_cells, 4)


def canonicalize_edges(src: jax.Array, dst: jax.Array, sgn: jax.Array):
    """(u=min, v=max, sgn) with self-loops sign-zeroed.

    Idempotent; every update path runs it so the sketch only ever sees
    the canonical undirected spelling of an edge.  Padding rows arrive
    with ``sgn == 0`` and stay that way.
    """
    u = jnp.minimum(src, dst)
    v = jnp.maximum(src, dst)
    sgn = jnp.where(u == v, jnp.int32(0), sgn.astype(jnp.int32))
    return u, v, sgn


def level_from_hash(h: jax.Array, n_levels: int) -> jax.Array:
    """int32 geometric level from a mixed uint32: compare-based
    ``Σ_{l=1}^{L-1} [h < 2^(32-l)]`` (== min(clz(h), L-1)).  Plain jnp
    uint32 ops so the Pallas kernel inlines the identical arithmetic."""
    if n_levels <= 1:
        return jnp.zeros(h.shape, jnp.int32)
    r = jax.lax.broadcasted_iota(jnp.uint32, (n_levels - 1,) + h.shape, 0)
    thr = jnp.uint32(1) << (jnp.uint32(31) - r)
    return jnp.sum((h[None] < thr).astype(jnp.int32), axis=0)


def edge_level(p: L0Params, u: jax.Array, v: jax.Array) -> jax.Array:
    """int32[E] level of each canonical edge."""
    h = hashing.mix32_pair(
        p.a_lvl[0], p.a_lvl[1], p.c_lvl[0], u.astype(jnp.uint32), v.astype(jnp.uint32)
    )
    return level_from_hash(h, p.n_levels)


def edge_cells(p: L0Params, u: jax.Array, v: jax.Array) -> jax.Array:
    """int32[d, E] cell index of each canonical edge in every table."""
    h = hashing.mix32_pair(
        p.a_cell[:, 0:1],
        p.a_cell[:, 1:2],
        p.c_cell[:, None],
        u.astype(jnp.uint32)[None, :],
        v.astype(jnp.uint32)[None, :],
    )
    return hashing.bucket32(h, p.n_cells)


def edge_fingerprint(p: L0Params, u: jax.Array, v: jax.Array) -> jax.Array:
    """uint32[E] fingerprint of each canonical edge."""
    return hashing.mix32_pair(
        p.a_fp[0], p.a_fp[1], p.c_fp[0], u.astype(jnp.uint32), v.astype(jnp.uint32)
    )


def l0_delta(
    src: jax.Array,  # int32[E] endpoint a (any order; canonicalized here)
    dst: jax.Array,  # int32[E] endpoint b
    sgn: jax.Array,  # int32[E] +1 insert / -1 delete / 0 padding
    params: L0Params,
    *,
    use_pallas: bool = True,
    block_e: int = 256,
    interpret: Optional[bool] = None,  # None: compiled on TPU, interpreter elsewhere
) -> jax.Array:
    """Sketch DELTA int32[L, d, C, 4] of one signed edge batch.

    Apply with ``tables + l0_delta(...)`` (see :func:`l0_update`); the
    delta itself is the sketch of the batch, so deltas merge by addition
    exactly like full sketches.
    """
    u, v, s = canonicalize_edges(src, dst, sgn)
    if not use_pallas:
        from repro.kernels.l0_sampler.ref import l0_delta_ref

        return l0_delta_ref(u, v, s, params)
    from repro.kernels.l0_sampler.kernel import l0_delta_pallas

    e = u.shape[0]
    pad = (-e) % block_e
    if pad:
        u = jnp.pad(u, (0, pad))
        v = jnp.pad(v, (0, pad))
        s = jnp.pad(s, (0, pad))
    return l0_delta_pallas(
        u,
        v,
        s,
        params.a_lvl,
        params.c_lvl,
        params.a_fp,
        params.c_fp,
        params.a_cell,
        params.c_cell,
        n_levels=params.n_levels,
        n_cells=params.n_cells,
        block_e=block_e,
        interpret=interpret,
    )


def l0_update(tables: jax.Array, src, dst, sgn, params: L0Params, **kw) -> jax.Array:
    """New sketch state: ``tables + l0_delta(src, dst, sgn, params)``."""
    return tables + l0_delta(src, dst, sgn, params, **kw)
