"""ℓ0-sampling sketch kernel family (turnstile runtime substrate).

Sibling of ``count_sketch/`` with the same split: ``ops.py`` is the public
jit'd wrapper + parameter plumbing, ``ref.py`` the pure-jnp oracle,
``kernel.py`` the Pallas TPU kernel (``interpret=None`` → compiled on TPU,
interpreter elsewhere).
"""

from repro.kernels.l0_sampler.ops import (
    L0Params,
    canonicalize_edges,
    edge_cells,
    edge_fingerprint,
    edge_level,
    l0_delta,
    l0_sketch_shape,
    l0_update,
    make_l0_params,
)

__all__ = [
    "L0Params",
    "canonicalize_edges",
    "edge_cells",
    "edge_fingerprint",
    "edge_level",
    "l0_delta",
    "l0_sketch_shape",
    "l0_update",
    "make_l0_params",
]
