"""Pallas TPU kernel: ℓ0-sampler sketch delta from a signed edge batch.

Same shape of solution as the Count-Sketch kernel: the data-dependent
scatter becomes a one-hot accumulate with the counter state resident in
VMEM across the edge-block grid dimension.  Two differences forced by the
ℓ0 structure:

* the flattened column space is ``L*C`` (levels × cells), far bigger than
  a Count-Sketch table, so the output is ALSO blocked over columns —
  grid ``(d, n_col_blocks, n_edge_blocks)`` with the edge dimension
  innermost, zero-init at ``eb == 0`` exactly like the Count-Sketch
  ``(t, n_edge_blocks)`` pattern;
* the four cell fields (count, sum_u, sum_v, fingerprint) are int32 with
  wrap-around semantics, and int32 matmul is not an MXU citizen — the
  one-hot contraction is a broadcast-multiply-sum on the VPU instead of
  ``jnp.dot``, chunked over columns to bound the live intermediate
  (``[4, block_e, col_chunk]`` int32).

Fields ride in sublane rows 0:4 of an (8, cols) block (sublane padding as
in the Count-Sketch kernel); the wrapper transposes back to the canonical
``[L, d, C, 4]`` sketch layout.

Cost model: a dense one-hot scatter is Θ(E · L·C) work per table, so the
kernel wants BATCHED updates (the turnstile driver pads batches to pow2
buckets precisely so this program caches and amortizes); the dispatch
rule keeps CPU runs on the segment-sum reference, which is the right
algorithm there.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import hashing
from repro.kernels.l0_sampler.ops import level_from_hash


def _l0_kernel(
    u_ref,
    v_ref,
    s_ref,
    al_ref,
    cl_ref,
    af_ref,
    cf_ref,
    ac_ref,
    cc_ref,
    out_ref,
    *,
    n_levels,
    n_cells,
    block_c,
    col_chunk,
):
    cb = pl.program_id(1)
    eb = pl.program_id(2)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    u = u_ref[0, :]
    v = v_ref[0, :]
    s = s_ref[0, :]
    uu = u.astype(jnp.uint32)
    vv = v.astype(jnp.uint32)

    # Shared pair-hash family (plain uint32 jnp ops, traceable here) —
    # bit-identical to the ops.py / ref.py spelling.
    h_lvl = hashing.mix32_pair(al_ref[0], al_ref[1], cl_ref[0], uu, vv)
    lvl = level_from_hash(h_lvl, n_levels)
    fp = hashing.mix32_pair(af_ref[0], af_ref[1], cf_ref[0], uu, vv)
    fp_i = jax.lax.bitcast_convert_type(fp, jnp.int32)
    cell = hashing.bucket32(
        hashing.mix32_pair(ac_ref[0, 0], ac_ref[0, 1], cc_ref[0], uu, vv), n_cells
    )

    # Flattened (level, cell) column, local to this column block.
    local = lvl * n_cells + cell - cb * block_c  # int32[E]
    vals = jnp.stack([s, s * u, s * v, s * fp_i])  # int32[4, E]

    def body(c, _):
        cols = (
            jax.lax.broadcasted_iota(jnp.int32, (local.shape[0], col_chunk), 1)
            + c * col_chunk
        )
        onehot = (local[:, None] == cols).astype(jnp.int32)  # [E, chunk]
        partial = jnp.sum(vals[:, :, None] * onehot[None, :, :], axis=1)  # [4, chunk]
        idx = pl.dslice(c * col_chunk, col_chunk)
        out_ref[0, 0:4, idx] += partial
        return _

    jax.lax.fori_loop(0, block_c // col_chunk, body, 0)


@functools.partial(
    jax.jit,
    static_argnames=("n_levels", "n_cells", "block_e", "block_c", "col_chunk", "interpret"),
)
def l0_delta_pallas(
    u: jax.Array,  # int32[E] canonical min endpoint
    v: jax.Array,  # int32[E] canonical max endpoint
    sgn: jax.Array,  # int32[E] ±1 insert/delete, 0 padding
    a_lvl: jax.Array,  # uint32[2]
    c_lvl: jax.Array,  # uint32[1]
    a_fp: jax.Array,  # uint32[2]
    c_fp: jax.Array,  # uint32[1]
    a_cell: jax.Array,  # uint32[d, 2]
    c_cell: jax.Array,  # uint32[d]
    *,
    n_levels: int,
    n_cells: int,
    block_e: int = 256,
    block_c: int | None = None,
    col_chunk: int = 256,
    interpret: bool | None = None,  # None: compiled on TPU, interpreter elsewhere
) -> jax.Array:
    """Returns the sketch delta int32[L, d, C, 4]."""
    from repro.kernels import resolve_interpret

    interpret = resolve_interpret(interpret)
    e = u.shape[0]
    d = a_cell.shape[0]
    n_cols = n_levels * n_cells
    if block_c is None:
        block_c = min(n_cols, 4096)
    col_chunk = min(col_chunk, block_c)
    assert e % block_e == 0, (e, block_e)
    assert n_cols % block_c == 0, (n_cols, block_c)
    assert block_c % col_chunk == 0, (block_c, col_chunk)
    n_eb = e // block_e
    n_cb = n_cols // block_c

    u2 = u.reshape(1, e)
    v2 = v.reshape(1, e)
    s2 = sgn.astype(jnp.int32).reshape(1, e)

    kern = functools.partial(
        _l0_kernel,
        n_levels=n_levels,
        n_cells=n_cells,
        block_c=block_c,
        col_chunk=col_chunk,
    )
    out = pl.pallas_call(
        kern,
        grid=(d, n_cb, n_eb),
        in_specs=[
            pl.BlockSpec((1, block_e), lambda j, c_, e_: (0, e_)),
            pl.BlockSpec((1, block_e), lambda j, c_, e_: (0, e_)),
            pl.BlockSpec((1, block_e), lambda j, c_, e_: (0, e_)),
            pl.BlockSpec((2,), lambda j, c_, e_: (0,)),
            pl.BlockSpec((1,), lambda j, c_, e_: (0,)),
            pl.BlockSpec((2,), lambda j, c_, e_: (0,)),
            pl.BlockSpec((1,), lambda j, c_, e_: (0,)),
            pl.BlockSpec((1, 2), lambda j, c_, e_: (j, 0)),
            pl.BlockSpec((1,), lambda j, c_, e_: (j,)),
        ],
        out_specs=pl.BlockSpec((1, 8, block_c), lambda j, c_, e_: (j, 0, c_)),
        out_shape=jax.ShapeDtypeStruct((d, 8, n_cols), jnp.int32),
        interpret=interpret,
    )(u2, v2, s2, a_lvl, c_lvl, a_fp, c_fp, a_cell, c_cell)
    # (d, 4, L*C) -> (d, 4, L, C) -> canonical [L, d, C, 4].
    return out[:, 0:4, :].reshape(d, 4, n_levels, n_cells).transpose(2, 0, 3, 1)
