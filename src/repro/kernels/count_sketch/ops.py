"""jit'd wrapper for the Count-Sketch update kernel, interface-compatible
with core/countsketch.py's SketchParams."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.countsketch import SketchParams
from repro.kernels.count_sketch.kernel import count_sketch_update_pallas
from repro.kernels.count_sketch.ref import count_sketch_update_ref


def count_sketch_update(
    endpoints: jax.Array,
    w: jax.Array,
    params: SketchParams,
    *,
    use_pallas: bool = True,
    block_e: int = 512,
    interpret: Optional[bool] = None,  # None: compiled on TPU, interpreter elsewhere
) -> jax.Array:
    """float32[t, b] counter tables from an endpoint stream."""
    if not use_pallas:
        return count_sketch_update_ref(endpoints, w, params)
    e = endpoints.shape[0]
    pad = (-e) % block_e
    if pad:
        endpoints = jnp.pad(endpoints, (0, pad))
        w = jnp.pad(w, (0, pad))
    return count_sketch_update_pallas(
        endpoints, w,
        params.a_h, params.c_h, params.a_g, params.c_g,
        n_buckets=params.n_buckets, block_e=block_e, interpret=interpret,
    )


def sketch_edges(edges_src, edges_dst, w_alive, params, **kw):
    """Both endpoints of every edge contribute (paper §5.1 update rule)."""
    endpoints = jnp.concatenate([edges_src, edges_dst])
    w = jnp.concatenate([w_alive, w_alive])
    return count_sketch_update(endpoints, w, params, **kw)
