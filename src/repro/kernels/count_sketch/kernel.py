"""Pallas TPU kernel: Count-Sketch counter update from an endpoint stream.

Paper §5.1 maintains t tables of b signed counters; every edge endpoint x
does ``c[i, h_i(x)] += g_i(x) * w``.  On TPU the data-dependent scatter
becomes a one-hot matmul, and — unlike the degree kernel — no pre-bucketing
is needed because the whole counter table is VMEM-resident (that is the
*point* of the sketch: O(t*b) state).

Grid: (t, n_endpoint_blocks).  Each step:
  * hashes one endpoint block with the table's multiply-shift parameters
    (uint32 arithmetic on the VPU),
  * builds onehot[e, c] = (bucket[e] == c) over the b counter columns,
  * accumulates ``(w * sign)[1, E] @ onehot[E, b]`` on the MXU into the
    table's counter row, which stays in VMEM across the block dimension.

VMEM per step (E_blk=512, b=8192): onehot 16 MB f32 is too big, so the
one-hot matmul is done in column chunks of 2048 inside the kernel
(fori_loop), keeping the live window ~4 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import hashing


def _cs_kernel(x_ref, w_ref, ah_ref, ch_ref, ag_ref, cg_ref, out_ref, *, n_buckets, col_chunk):
    eb = pl.program_id(1)

    @pl.when(eb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[0, :].astype(jnp.uint32)
    w = w_ref[0, :]
    a_h = ah_ref[0]
    c_h = ch_ref[0]
    a_g = ag_ref[0]
    c_g = cg_ref[0]

    # Shared multiply-shift family (plain uint32 jnp ops, traceable here).
    bucket = hashing.bucket32(hashing.mix32(a_h, c_h, x), n_buckets)
    sign = hashing.sign32(hashing.mix32(a_g, c_g, x))
    val = (w * sign)[None, :]  # [1, E]

    n_chunks = n_buckets // col_chunk

    def body(c, _):
        cols = jax.lax.broadcasted_iota(
            jnp.int32, (bucket.shape[0], col_chunk), 1
        ) + c * col_chunk
        onehot = (bucket[:, None] == cols).astype(jnp.float32)
        partial = jnp.dot(val, onehot, preferred_element_type=jnp.float32)
        idx = pl.dslice(c * col_chunk, col_chunk)
        out_ref[0, 0:1, idx] += partial
        return _

    jax.lax.fori_loop(0, n_chunks, body, 0)


@functools.partial(
    jax.jit, static_argnames=("n_buckets", "block_e", "col_chunk", "interpret")
)
def count_sketch_update_pallas(
    endpoints: jax.Array,  # int32[E] endpoint node ids (stream order)
    w: jax.Array,  # float32[E] weight contribution (0 for dead/padding)
    a_h: jax.Array,  # uint32[t]
    c_h: jax.Array,  # uint32[t]
    a_g: jax.Array,  # uint32[t]
    c_g: jax.Array,  # uint32[t]
    *,
    n_buckets: int,
    block_e: int = 512,
    col_chunk: int = 2048,
    interpret: bool | None = None,  # None: compiled on TPU, interpreter elsewhere
) -> jax.Array:
    """Returns float32[t, n_buckets] counter tables."""
    from repro.kernels import resolve_interpret

    interpret = resolve_interpret(interpret)
    e = endpoints.shape[0]
    t = a_h.shape[0]
    assert e % block_e == 0, (e, block_e)
    col_chunk = min(col_chunk, n_buckets)
    assert n_buckets % col_chunk == 0
    n_eb = e // block_e

    x2 = endpoints.reshape(1, e)
    w2 = w.reshape(1, e)

    kern = functools.partial(_cs_kernel, n_buckets=n_buckets, col_chunk=col_chunk)
    out = pl.pallas_call(
        kern,
        grid=(t, n_eb),
        in_specs=[
            pl.BlockSpec((1, block_e), lambda i, e_: (0, e_)),
            pl.BlockSpec((1, block_e), lambda i, e_: (0, e_)),
            pl.BlockSpec((1,), lambda i, e_: (i,)),
            pl.BlockSpec((1,), lambda i, e_: (i,)),
            pl.BlockSpec((1,), lambda i, e_: (i,)),
            pl.BlockSpec((1,), lambda i, e_: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 8, n_buckets), lambda i, e_: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, 8, n_buckets), jnp.float32),
        interpret=interpret,
    )(x2, w2, a_h, c_h, a_g, c_g)
    return out[:, 0, :]
