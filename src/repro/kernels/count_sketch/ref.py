"""Pure-jnp oracle: exactly core/countsketch.py's update path, reshaped to
the kernel's (endpoints, weights) interface."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.countsketch import SketchParams, _hash_bucket, _hash_sign


def count_sketch_update_ref(
    endpoints: jax.Array,  # int32[E]
    w: jax.Array,  # float32[E]
    params: SketchParams,
) -> jax.Array:
    t, b = params.n_tables, params.n_buckets
    buckets = _hash_bucket(params, endpoints)  # [t, E]
    signs = _hash_sign(params, endpoints)  # [t, E]
    flat = (buckets + (jnp.arange(t, dtype=jnp.int32) * b)[:, None]).reshape(-1)
    vals = (signs * w[None, :]).reshape(-1)
    return jax.ops.segment_sum(vals, flat, num_segments=t * b).reshape(t, b)
