from repro.kernels.count_sketch.ops import count_sketch_update
