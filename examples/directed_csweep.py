"""Directed densest subgraph (Algorithm 3) with the paper's geometric c-grid.

    PYTHONPATH=src python examples/directed_csweep.py

Builds a directed graph with a planted S->T dense block (|S|/|T| = 2.5) and
sweeps c = |S|/|T| guesses at delta=2, printing the Fig 6.4-style profile.
The best c should land near the planted ratio and recover the planted sets.
"""

import numpy as np

from repro.core import densest_directed_search
from repro.core.peel_directed import c_grid, densest_subgraph_directed
from repro.graph.generators import directed_planted


def main():
    ks, kt = 100, 40
    edges, s_ids, t_ids = directed_planted(
        n=20_000, avg_deg=6.0, ks=ks, kt=kt, p_dense=0.5, seed=3
    )
    print(f"graph: n={edges.n_nodes} m={int(edges.num_real_edges())} "
          f"planted |S|={ks} |T|={kt} (c* = {ks / kt:.2f})")

    best, best_c, rhos, passes = densest_directed_search(edges, eps=0.5, delta=2.0)
    grid = c_grid(edges.n_nodes, 2.0)
    for c, rho, p in zip(grid, rhos, passes):
        bar = "#" * int(40 * rho / max(rhos.max(), 1e-9))
        marker = "  <== best" if abs(c - best_c) < 1e-9 else ""
        if 0.01 <= c <= 100:
            print(f"c={c:9.3f} rho={rho:8.3f} passes={p:2d} {bar}{marker}")

    s_found = np.nonzero(np.asarray(best.best_s))[0]
    t_found = np.nonzero(np.asarray(best.best_t))[0]
    s_rec = len(np.intersect1d(s_found, s_ids)) / ks
    t_rec = len(np.intersect1d(t_found, t_ids)) / kt
    print(
        f"\nbest: c={best_c:.3f} rho={float(best.best_density):.3f} "
        f"|S|={len(s_found)} |T|={len(t_found)} "
        f"planted recall S={s_rec:.0%} T={t_rec:.0%}"
    )


if __name__ == "__main__":
    main()
