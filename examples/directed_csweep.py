"""Directed densest subgraph (Algorithm 3) with the paper's geometric c-grid.

    PYTHONPATH=src python examples/directed_csweep.py

Builds a directed graph with a planted S->T dense block (|S|/|T| = 2.5) and
solves ``Problem.directed()`` — c=None means "sweep the c grid"; the whole
profile comes back in ``result.extras`` and every c reuses ONE compiled
program.  The best c should land near the planted ratio and recover the
planted sets.
"""

import numpy as np

from repro.core import Problem, solve
from repro.graph.generators import directed_planted


def main():
    ks, kt = 100, 40
    edges, s_ids, t_ids = directed_planted(
        n=20_000, avg_deg=6.0, ks=ks, kt=kt, p_dense=0.5, seed=3
    )
    print(f"graph: n={edges.n_nodes} m={int(edges.num_real_edges())} "
          f"planted |S|={ks} |T|={kt} (c* = {ks / kt:.2f})")

    best = solve(edges, Problem.directed(eps=0.5, c_delta=2.0))
    best_c = best.extras["best_c"]
    grid = best.extras["c_grid"]
    rhos = best.extras["c_density"]
    passes = best.extras["c_passes"]
    for c, rho, p in zip(grid, rhos, passes):
        bar = "#" * int(40 * rho / max(rhos.max(), 1e-9))
        marker = "  <== best" if abs(c - best_c) < 1e-9 else ""
        if 0.01 <= c <= 100:
            print(f"c={c:9.3f} rho={rho:8.3f} passes={p:2d} {bar}{marker}")

    s_found = best.nodes()
    t_found = best.t_nodes()
    s_rec = len(np.intersect1d(s_found, s_ids)) / ks
    t_rec = len(np.intersect1d(t_found, t_ids)) / kt
    print(
        f"\nbest: c={best_c:.3f} rho={float(best.best_density):.3f} "
        f"|S|={len(s_found)} |T|={len(t_found)} "
        f"planted recall S={s_rec:.0%} T={t_rec:.0%}"
    )


if __name__ == "__main__":
    main()
