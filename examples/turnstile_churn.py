"""Dynamic graph stream — maintain the densest subgraph under churn.

    PYTHONPATH=src python examples/turnstile_churn.py [--n 50000]

The other substrates consume insert-only streams; this example drives the
TURNSTILE runtime (core/turnstile.py): edges arrive in batches of
insertions AND deletions, an ℓ0-sampling sketch absorbs them on device,
and "how dense is the graph right now?" is answered between batches by
recovering the sketch's uniform edge sample and peeling only the sample —
(1+eps)(2+2eps)-approximate, with O(tau·log n) memory independent of the
stream length.

The script simulates a live service:

  1. a power-law graph with a planted dense block arrives in insert
     batches; after each, :class:`repro.serve.TurnstileDensityService`
     reports the current density (watch it jump when the block lands);
  2. churn deletes a third of the stream — including most of the planted
     block — and the density falls back;
  3. every reported density is checked against an exact insert-mode peel
     of the surviving graph (:func:`repro.graph.edgelist.apply_updates`
     host reference) — the MTVV envelope holds at every step;
  4. repeated reads between updates are served from the service's cache
     (zero recomputation), and the sketch's ``trace_count`` shows every
     same-bucket update batch reused ONE compiled program.
"""

import argparse
import time

import numpy as np

from repro.core import Problem, solve
from repro.graph.edgelist import apply_updates, from_numpy
from repro.graph.generators import planted_dense_subgraph
from repro.serve import TurnstileDensityService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--avg-deg", type=float, default=6.0)
    ap.add_argument("--planted-k", type=int, default=200)
    ap.add_argument("--planted-p", type=float, default=0.5)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--eps", type=float, default=0.3)
    ap.add_argument("--sample-edges", type=int, default=1 << 13)
    args = ap.parse_args(argv)

    g, planted = planted_dense_subgraph(
        args.n, args.avg_deg, args.planted_k, args.planted_p, seed=0
    )
    m = int(np.asarray(g.mask).sum())
    src = np.asarray(g.src)[:m].copy()
    dst = np.asarray(g.dst)[:m].copy()
    envelope = (1 + args.eps) * (2 + 2 * args.eps)
    print(f"stream: {m} edges over {args.n} nodes, "
          f"{len(planted)}-node planted block, envelope {envelope:.2f}x")

    svc = TurnstileDensityService(
        args.n,
        Problem.undirected(
            eps=args.eps, stream_mode="turnstile",
            sample_edges=args.sample_edges,
        ),
    )
    exact_prob = Problem.undirected(eps=args.eps, compaction="off")

    def check(live_edges, label):
        t0 = time.perf_counter()
        est = svc.density()
        dt = time.perf_counter() - t0
        exact = float(solve(live_edges, exact_prob).best_density)
        ratio = est / max(exact, 1e-9)
        ok = 1.0 / envelope <= ratio <= envelope
        lvl = svc.result().extras["turnstile"]["level"]
        print(f"  {label}: density ~{est:8.2f}  exact {exact:8.2f}  "
              f"ratio {ratio:.3f} {'OK' if ok else 'OUT OF ENVELOPE'}  "
              f"(sample level {lvl}, query {dt * 1e3:.1f} ms)")
        assert ok, f"{label}: ratio {ratio} outside {envelope}"

    # -- 1. the graph arrives in insert batches ---------------------------
    print(f"\ninserting in {args.batches} batches:")
    live = None
    step = -(-m // args.batches)
    for b in range(args.batches):
        lo, hi = b * step, min((b + 1) * step, m)
        batch = np.stack([src[lo:hi], dst[lo:hi]], axis=1)
        svc.apply(insert_edges=batch)
        if live is None:
            live = from_numpy(src[lo:hi], dst[lo:hi], args.n)
        else:
            live, _ = apply_updates(live, inserts=batch)
        check(live, f"after insert batch {b + 1}/{args.batches}")

    # -- 2. churn: delete a third of the stream, planted block first ------
    rng = np.random.default_rng(1)
    block = np.isin(src, planted) & np.isin(dst, planted)
    background = np.nonzero(~block)[0]
    kill = np.concatenate([
        np.nonzero(block)[0],
        rng.choice(background, size=m // 3 - int(block.sum()), replace=False),
    ])
    deletes = np.stack([src[kill], dst[kill]], axis=1)
    print(f"\nchurn: deleting {len(kill)} edges "
          f"({int(block.sum())} of them from the planted block):")
    svc.apply(delete_edges=deletes)
    live, stats = apply_updates(live, deletes=deletes)
    assert stats["missing_deletes"] == 0
    check(live, "after churn")

    # -- 3. reads between updates hit the cache ---------------------------
    for _ in range(100):
        svc.density()
    s = svc.stats()
    print(f"\nservice stats: {s}")
    assert s["queries_computed"] == args.batches + 1, s
    print(f"  {s['queries_served']} reads served by "
          f"{s['queries_computed']} sampled peels; "
          f"{s['batches_applied']} update batches traced "
          f"{s['update_trace_count']} program(s)")


if __name__ == "__main__":
    main()
