"""Quickstart: the front-door API on a planted dense block.

    PYTHONPATH=src python examples/quickstart.py [--n 4000] [--k 80]

Declare a :class:`Problem`, call :func:`solve`, get a
:class:`DenseSubgraphResult` — then sweep eps as ONE compiled program with
:func:`solve_batch` and compare against the exact max-flow optimum and
Charikar's node-at-a-time greedy (the paper's Table 2 in miniature).
"""

import argparse
import time

import numpy as np

from repro.core import (
    Problem,
    charikar_greedy,
    densest_subgraph_exact,
    solve,
    solve_batch,
)
from repro.graph.generators import planted_dense_subgraph


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--avg-deg", type=float, default=5.0)
    ap.add_argument("--k", type=int, default=80)
    args = ap.parse_args(argv)

    edges, planted = planted_dense_subgraph(
        n=args.n, avg_deg=args.avg_deg, k=args.k, p_dense=0.6, seed=7
    )
    print(f"graph: n={edges.n_nodes} m={int(edges.num_real_edges())} "
          f"(planted {len(planted)}-node dense block)")

    _, rho_star = densest_subgraph_exact(edges)
    print(f"exact optimum rho* = {rho_star:.4f} (Goldberg max-flow)")

    _, rho_greedy = charikar_greedy(edges)
    print(f"charikar greedy    = {rho_greedy:.4f} "
          f"(ratio {rho_star / rho_greedy:.3f})")

    # --- one Problem, one solve ------------------------------------------
    eps_grid = (0.1, 0.5, 1.0)
    for eps in eps_grid:
        t0 = time.time()
        res = solve(edges, Problem.undirected(eps=eps))
        nodes = res.nodes()
        rho = float(res.best_density)
        overlap = len(np.intersect1d(nodes, planted)) / len(planted)
        print(
            f"peel eps={eps:<4} rho={rho:.4f} ratio={rho_star / rho:.3f} "
            f"passes={int(res.passes)} |S|={len(nodes)} "
            f"planted-recall={overlap:.0%} ({time.time() - t0:.2f}s) "
            f"[{res.provenance.policy} x {res.provenance.backend} "
            f"x {res.provenance.substrate}]"
        )
        assert rho_star / rho <= 2 * (1 + eps) + 1e-6  # Lemma 3

    # --- the whole eps sweep as ONE XLA program ---------------------------
    t0 = time.time()
    batch = solve_batch(
        edges, Problem.undirected(max_passes=64), eps=list(eps_grid)
    )
    rhos = np.asarray(batch.best_density)
    print(
        f"solve_batch eps={eps_grid}: rho={np.round(rhos, 4).tolist()} "
        f"in one program ({time.time() - t0:.2f}s)"
    )
    for eps, rho in zip(eps_grid, rhos):
        assert rho_star / rho <= 2 * (1 + eps) + 1e-6


if __name__ == "__main__":
    main()
