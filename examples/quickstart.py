"""Quickstart: find an (approximately) densest subgraph with Algorithm 1.

    PYTHONPATH=src python examples/quickstart.py

Generates a power-law graph with a planted dense block, runs the one-XLA-
program peel at a few eps settings, and compares against the exact max-flow
optimum and Charikar's node-at-a-time greedy — the paper's Table 2 in
miniature.
"""

import time

import numpy as np

from repro.core import (
    charikar_greedy,
    densest_subgraph,
    densest_subgraph_exact,
    densest_subgraph_sets,
)
from repro.graph.generators import planted_dense_subgraph


def main():
    edges, planted = planted_dense_subgraph(
        n=4000, avg_deg=5.0, k=80, p_dense=0.6, seed=7
    )
    print(f"graph: n={edges.n_nodes} m={int(edges.num_real_edges())} "
          f"(planted {len(planted)}-node dense block)")

    _, rho_star = densest_subgraph_exact(edges)
    print(f"exact optimum rho* = {rho_star:.4f} (Goldberg max-flow)")

    _, rho_greedy = charikar_greedy(edges)
    print(f"charikar greedy    = {rho_greedy:.4f} "
          f"(ratio {rho_star / rho_greedy:.3f})")

    for eps in (0.1, 0.5, 1.0):
        t0 = time.time()
        nodes, rho = densest_subgraph_sets(edges, eps=eps)
        res = densest_subgraph(edges, eps=eps)
        overlap = len(np.intersect1d(nodes, planted)) / len(planted)
        print(
            f"peel eps={eps:<4} rho={rho:.4f} ratio={rho_star / rho:.3f} "
            f"passes={int(res.passes)} |S|={len(nodes)} "
            f"planted-recall={overlap:.0%} ({time.time() - t0:.2f}s)"
        )
        assert rho_star / rho <= 2 * (1 + eps) + 1e-6  # Lemma 3


if __name__ == "__main__":
    main()
