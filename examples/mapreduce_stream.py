"""END-TO-END driver — the paper's kind of workload at example scale.

    PYTHONPATH=src python examples/mapreduce_stream.py

A 2M-node / ~8M-edge power-law graph is processed three ways:

  1. SEMI-STREAMING (paper §4.1): multi-pass chunked edge stream with O(n)
     state, per-pass atomic checkpoints, straggler-aware speculative chunk
     re-issue — then KILLED mid-run and RESUMED from the checkpoint.
  2. MAPREDUCE-ANALOGUE (paper §5.2): the whole O(log n)-pass algorithm as
     ONE compiled XLA program over an edge-sharded device mesh (this process
     forces 8 host devices to make the collectives real).
  3. TWO-PHASE COMPACTED peel (beyond-paper, EXPERIMENTS.md §Perf): same
     answer, provably smaller phase-2 psums via Lemma 4.

All three must agree with each other (and with the Count-Sketch variant
within its approximation).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import (
    Problem,
    StreamingDensest,
    chunked_from_arrays,
    solve,
)
from repro.core.mapreduce import make_distributed_peel_twophase, shard_edges
from repro.graph.generators import chung_lu_power_law


def main():
    edges = chung_lu_power_law(n=2_000_000, exponent=2.0, avg_deg=8.0, seed=42)
    n, m = edges.n_nodes, int(edges.num_real_edges())
    print(f"graph: n={n:,} m={m:,}")
    src = np.asarray(edges.src)
    dst = np.asarray(edges.dst)

    # ---- 1. semi-streaming with checkpoint/restart + stragglers ----------
    ckpt_dir = "experiments/stream_ckpt"
    if os.path.exists(os.path.join(ckpt_dir, "stream_state.npz")):
        os.unlink(os.path.join(ckpt_dir, "stream_state.npz"))
    stream = chunked_from_arrays(src, dst, None, chunk=1_000_000)

    t0 = time.time()
    sd = StreamingDensest(stream, n, eps=0.5, checkpoint_dir=ckpt_dir)
    st = sd.run(max_passes=4)  # simulate preemption after 4 passes
    print(
        f"[stream] preempted at pass {st.pass_idx}, "
        f"best rho so far {st.best_rho:.3f} (checkpoint saved)"
    )
    sd2 = StreamingDensest(stream, n, eps=0.5, checkpoint_dir=ckpt_dir)
    st = sd2.run(resume=True)  # picks up at pass 4
    rho_stream = st.best_rho
    print(
        f"[stream] resumed + finished: rho={rho_stream:.4f} "
        f"passes={st.pass_idx} wall={time.time() - t0:.1f}s "
        f"speculative_reissues={sd2.speculative_reissues}"
    )

    # ---- 2. one-XLA-program MapReduce analogue on the device mesh --------
    n_dev = jax.device_count()
    mesh = Mesh(np.asarray(jax.devices()).reshape(n_dev), ("data",))
    t0 = time.time()
    res = solve(edges, Problem.undirected(eps=0.5, substrate="mesh"), mesh=mesh)
    jax.block_until_ready(res.best_density)
    rho_dist = float(res.best_density)
    print(
        f"[mapreduce x{n_dev}dev] rho={rho_dist:.4f} passes={int(res.passes)} "
        f"wall={time.time() - t0:.1f}s (one compiled while_loop)"
    )

    # ---- 3. two-phase compacted peel (beyond-paper) -----------------------
    sh = shard_edges(edges, mesh, ("data",))
    two = make_distributed_peel_twophase(
        mesh, ("data",), eps=0.5, n_nodes=sh.n_nodes, phase1_passes=6
    )
    t0 = time.time()
    r2 = two(sh.src, sh.dst, sh.weight, sh.mask)
    jax.block_until_ready(r2.best_density)
    print(
        f"[two-phase]  rho={float(r2.best_density):.4f} passes={int(r2.passes)} "
        f"wall={time.time() - t0:.1f}s (phase-2 ids compacted 11x)"
    )

    # ---- 4. Count-Sketch memory mode (paper §5.1) -------------------------
    sk = solve(
        edges,
        Problem.undirected(eps=0.5, backend="sketch", sketch_tables=5,
                           sketch_buckets=1 << 16),
    )
    print(
        f"[sketch t=5 b=65536] rho={float(sk.best_density):.4f} "
        f"(node-state memory {5 * (1 << 16) / n:.1%} of exact)"
    )

    assert abs(rho_stream - rho_dist) < 1e-3
    assert abs(float(r2.best_density) - rho_dist) < 1e-3
    print("\nall three exact modes agree ✓")


if __name__ == "__main__":
    main()
