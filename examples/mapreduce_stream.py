"""END-TO-END driver — the paper's kind of workload at example scale.

    PYTHONPATH=src python examples/mapreduce_stream.py [--n 2000000]

A power-law graph (2M nodes / ~8M edges by default) is processed four ways:

  1. SEMI-STREAMING (paper §4.1): multi-pass chunked edge stream with O(n)
     state, per-pass atomic checkpoints, straggler-aware speculative chunk
     re-issue — then KILLED mid-run and RESUMED from the checkpoint.
  2. OUT-OF-CORE SPILL LADDER: the same stream written once to an on-disk
     memmap edge store and run through the geometric compaction ladder with
     a residency cap SMALLER than the ladder's survivors — the rebuilt
     survivor streams spill to disk, so host RAM holds only the async
     pipeline's prefetch window.
  3. MAPREDUCE-ANALOGUE (paper §5.2): the whole O(log n)-pass algorithm as
     ONE compiled XLA program over an edge-sharded device mesh (this process
     forces 8 host devices to make the collectives real).
  4. TWO-PHASE COMPACTED peel (beyond-paper): same answer, provably smaller
     phase-2 psums via Lemma 4; plus the Count-Sketch memory mode (§5.1).

All exact modes must agree (and the Count-Sketch variant within its
approximation).
"""

import argparse
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import (
    Problem,
    StreamingDensest,
    chunked_from_arrays,
    chunked_from_memmap,
    solve,
)
from repro.core.mapreduce import make_distributed_peel_twophase, shard_edges
from repro.graph.edgelist import save_edges_memmap
from repro.graph.generators import chung_lu_power_law


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2_000_000)
    ap.add_argument("--avg-deg", type=float, default=8.0)
    ap.add_argument("--chunk", type=int, default=None,
                    help="stream chunk size (default: ~m/8)")
    ap.add_argument("--scratch", default="experiments/stream_ckpt",
                    help="checkpoint / edge-store / spill scratch dir")
    args = ap.parse_args(argv)

    edges = chung_lu_power_law(
        n=args.n, exponent=2.0, avg_deg=args.avg_deg, seed=42
    )
    n, m = edges.n_nodes, int(edges.num_real_edges())
    chunk = args.chunk or max(m // 8, 1024)
    print(f"graph: n={n:,} m={m:,} chunk={chunk:,}")
    src = np.asarray(edges.src)
    dst = np.asarray(edges.dst)

    # ---- 1. semi-streaming with checkpoint/restart + stragglers ----------
    ckpt_dir = args.scratch
    if os.path.exists(os.path.join(ckpt_dir, "stream_state.npz")):
        os.unlink(os.path.join(ckpt_dir, "stream_state.npz"))
    stream = chunked_from_arrays(src, dst, None, chunk=chunk)

    t0 = time.time()
    sd = StreamingDensest(stream, n, eps=0.5, checkpoint_dir=ckpt_dir)
    st = sd.run(max_passes=4)  # simulate preemption after 4 passes
    print(
        f"[stream] preempted at pass {st.pass_idx}, "
        f"best rho so far {st.best_rho:.3f} (checkpoint saved)"
    )
    sd2 = StreamingDensest(stream, n, eps=0.5, checkpoint_dir=ckpt_dir)
    st = sd2.run(resume=True)  # picks up at pass 4
    rho_stream = st.best_rho
    print(
        f"[stream] resumed + finished: rho={rho_stream:.4f} "
        f"passes={st.pass_idx} wall={time.time() - t0:.1f}s "
        f"speculative_reissues={sd2.speculative_reissues}"
    )

    # ---- 1b. out-of-core: memmap store + spilled compaction ladder -------
    # The edge store lives on disk; the residency cap is far below the
    # ladder's survivor count, so every rebuilt survivor stream spills to
    # memmaps under spill_dir and host RAM holds only the prefetch window.
    store = save_edges_memmap(
        os.path.join(args.scratch, "edge_store"), src, dst
    )
    chunk_ooc = max(m // 64, 256)
    # Rebuilt spill chunks are pow2-padded (<= 2x the input chunk), so the
    # pipeline's 4-chunk window is bounded by 8 x chunk_ooc ~ m/8 — far
    # below the ladder's survivor count (just under m/2 at first trigger).
    cap = 8 * chunk_ooc
    t0 = time.time()
    ooc = StreamingDensest(
        chunked_from_memmap(store, chunk=chunk_ooc), n, eps=0.5,
        compaction="geometric", prefetch=4,
        spill_dir=os.path.join(args.scratch, "spill"),
        residency_cap_edges=cap,
    )
    st_ooc = ooc.run(resume=False)
    print(
        f"[out-of-core] rho={st_ooc.best_rho:.4f} passes={st_ooc.pass_idx} "
        f"wall={time.time() - t0:.1f}s spill_rungs={ooc.spill_rungs} "
        f"peak_resident={ooc.peak_resident_edges:,}/{m:,} edges "
        f"(cap {cap:,})"
    )
    assert ooc.peak_resident_edges <= cap
    assert st_ooc.best_rho == rho_stream
    assert (st_ooc.best_alive == st.best_alive).all()

    # ---- 2. one-XLA-program MapReduce analogue on the device mesh --------
    n_dev = jax.device_count()
    mesh = Mesh(np.asarray(jax.devices()).reshape(n_dev), ("data",))
    t0 = time.time()
    res = solve(edges, Problem.undirected(eps=0.5, substrate="mesh"), mesh=mesh)
    jax.block_until_ready(res.best_density)
    rho_dist = float(res.best_density)
    print(
        f"[mapreduce x{n_dev}dev] rho={rho_dist:.4f} passes={int(res.passes)} "
        f"wall={time.time() - t0:.1f}s (one compiled while_loop)"
    )

    # ---- 3. two-phase compacted peel (beyond-paper) -----------------------
    sh = shard_edges(edges, mesh, ("data",))
    two = make_distributed_peel_twophase(
        mesh, ("data",), eps=0.5, n_nodes=sh.n_nodes, phase1_passes=6
    )
    t0 = time.time()
    r2 = two(sh.src, sh.dst, sh.weight, sh.mask)
    jax.block_until_ready(r2.best_density)
    print(
        f"[two-phase]  rho={float(r2.best_density):.4f} passes={int(r2.passes)} "
        f"wall={time.time() - t0:.1f}s (phase-2 ids compacted)"
    )

    # ---- 4. Count-Sketch memory mode (paper §5.1) -------------------------
    sk = solve(
        edges,
        Problem.undirected(eps=0.5, backend="sketch", sketch_tables=5,
                           sketch_buckets=1 << 16),
    )
    print(
        f"[sketch t=5 b=65536] rho={float(sk.best_density):.4f} "
        f"(node-state memory {5 * (1 << 16) / n:.1%} of exact)"
    )

    assert abs(rho_stream - rho_dist) < 1e-3
    assert abs(float(r2.best_density) - rho_dist) < 1e-3
    print("\nall exact modes agree ✓")


if __name__ == "__main__":
    main()
