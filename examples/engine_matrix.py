"""The PeelEngine policy × backend matrix on one graph.

Every cell below is the SAME pass body (core/engine.py run_peel): only the
removal policy and the degree backend change.  The front door reaches the
same cells declaratively — ``solve(edges, Problem(objective=..., backend=...))``
— and the closing lines prove it on one cell.  Run with::

    PYTHONPATH=src python examples/engine_matrix.py [--n 1000]
"""

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import densest_subgraph_exact
from repro.core.countsketch import SketchBackend, make_sketch_params
from repro.core.engine import (
    AtLeastKFraction,
    DirectedST,
    ExactBackend,
    UndirectedThreshold,
    run_peel,
)
from repro.graph.generators import directed_planted, planted_dense_subgraph


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1000)
    args = ap.parse_args(argv)

    eps, mp = 0.5, 64
    n = args.n
    edges, planted = planted_dense_subgraph(
        n, avg_deg=4, k=max(10, n // 25), p_dense=0.8, seed=0
    )
    dedges, _, _ = directed_planted(
        n, avg_deg=3, ks=max(8, n // 33), kt=max(6, n // 40), p_dense=0.9, seed=0
    )
    _, rho_star = densest_subgraph_exact(edges)
    print(f"undirected n={edges.n_nodes} planted k={len(planted)} rho*={rho_star:.3f}")

    from repro.kernels.peel_degree.ops import (
        degree_backend_from_tiling,
        tiling_for_edges,
    )

    backends = {
        "exact": ExactBackend(),
        "sketch": SketchBackend(make_sketch_params(t=5, b=1 << 13, seed=1)),
        "pallas": degree_backend_from_tiling(tiling_for_edges(edges, tile_size=256)),
    }
    policies = {
        "undirected_threshold": (UndirectedThreshold(eps), edges),
        "at_least_k(100)": (AtLeastKFraction(k=100, eps=eps), edges),
        "directed_st(c=1)": (DirectedST(eps=eps, c=jnp.float32(1.0)), dedges),
    }

    print(f"\n{'policy':<22} {'backend':<8} {'rho':>8} {'|S|':>6} {'passes':>7}")
    for pname, (policy, g) in policies.items():
        for bname, backend in backends.items():
            if policy.directed and bname == "pallas":
                continue  # tiled kernel counts both endpoints (undirected)
            res = jax.jit(lambda e, p=policy, b=backend: run_peel(e, p, b, mp))(g)
            print(
                f"{pname:<22} {bname:<8} {float(res.best_density):8.3f} "
                f"{int(res.best_size):6d} {int(res.passes):7d}"
            )

    # The declarative route to the same cell (front door, core/api.py).
    from repro.core import Problem, solve

    front = solve(edges, Problem.undirected(eps=eps, max_passes=mp))
    direct = jax.jit(
        lambda e: run_peel(e, UndirectedThreshold(eps), ExactBackend(), mp)
    )(edges)
    assert np.array_equal(np.asarray(front.best_alive), np.asarray(direct.best_alive))
    print(
        f"\nsolve(Problem.undirected(eps={eps})) == engine cell "
        f"[{front.provenance.policy} x {front.provenance.backend}] ✓"
    )


if __name__ == "__main__":
    main()
