"""Community mining -> GNN training: the paper's community application
feeding the framework's training stack end-to-end.

    PYTHONPATH=src python examples/community_gnn.py

1. Iteratively peels node-disjoint dense communities out of a planted-
   partition graph (the paper's §6 enumeration note).
2. Uses community membership as (noisy) node labels and trains GraphSAGE
   with the real layered neighbor sampler, the fault-tolerant Trainer and
   async checkpointing — then restarts from the checkpoint to show the
   resume path.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import densest_subgraph_sets
from repro.graph.edgelist import from_numpy
from repro.graph.generators import planted_partition
from repro.graph.sampler import CSRGraph, LayeredSampler


def peel_communities(edges, k_communities: int, eps: float = 0.5):
    """Node-disjoint (approx) densest subgraphs, greedily removed."""
    src = np.asarray(edges.src)
    dst = np.asarray(edges.dst)
    n = edges.n_nodes
    removed = np.zeros(n, bool)
    communities = []
    for _ in range(k_communities):
        keep = ~(removed[src] | removed[dst])
        sub = from_numpy(src[keep], dst[keep], n)
        nodes, rho = densest_subgraph_sets(sub, eps=eps)
        nodes = np.asarray([u for u in nodes if not removed[u]])
        if len(nodes) == 0:
            break
        communities.append((nodes, rho))
        removed[nodes] = True
    return communities


def main():
    n, k = 3000, 4
    # Heterogeneous densities: the peel extracts communities densest-first
    # (with uniform p_in the UNION has the same density as each block and
    # the algorithm correctly returns the whole graph).
    edges, truth = planted_partition(
        n=n, k=k, p_in=(0.20, 0.12, 0.08, 0.05), p_out=0.0005, seed=11
    )
    print(f"graph: n={n} m={int(edges.num_real_edges())}, {k} planted communities")

    comms = peel_communities(edges, k)
    labels = np.full(n, k, np.int32)  # background class k
    for ci, (nodes, rho) in enumerate(comms):
        labels[nodes] = ci
        purity = np.bincount(truth[nodes], minlength=k).max() / len(nodes)
        print(f"community {ci}: |S|={len(nodes):4d} rho={rho:6.2f} purity={purity:.0%}")

    # ---- GraphSAGE on the mined labels ------------------------------------
    import dataclasses

    from repro.configs import get_arch
    from repro.optim import AdamWConfig, apply_updates, init_state
    from repro.train.step import init_model_params, make_loss_fn, specialize_gnn_config
    from repro.train.trainer import Trainer, TrainerConfig

    spec = get_arch("graphsage-reddit")
    cfg = specialize_gnn_config(
        spec.reduced_config, dict(d_feat=16, n_classes=k + 1)
    )
    g = CSRGraph.from_edges(np.asarray(edges.src), np.asarray(edges.dst), n)
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((n, 16)).astype(np.float32)
    feats[:, 0] = labels == 0  # weakly informative features
    feats_j = jnp.asarray(feats)
    sampler = LayeredSampler(g, labels, batch_nodes=64, fanout=(5, 3), seed=1)

    class SamplerStream:
        def __init__(self, s):
            self.s = s

        def __next__(self):
            b = next(self.s)
            return {
                "feat_table": feats_j,
                **{kk: jnp.asarray(v) for kk, v in b.items()},
            }

        def checkpoint_state(self):
            return self.s.checkpoint_state()

        def restore(self, st):
            self.s.restore(st)

    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    loss_fn = make_loss_fn(spec, "sampled_train", cfg=cfg)

    @jax.jit
    def step_fn(state, batch):
        params, opt = state
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch=batch
        )
        params, opt, om = apply_updates(params, grads, opt, opt_cfg)
        return (params, opt), {**metrics, **om}

    params = init_model_params(spec, jax.random.PRNGKey(0), cfg=cfg)
    import shutil

    shutil.rmtree("experiments/community_gnn_ckpt", ignore_errors=True)
    tcfg = TrainerConfig(
        total_steps=150, ckpt_dir="experiments/community_gnn_ckpt", ckpt_every=50,
    )
    tr = Trainer(tcfg, step_fn, (params, init_state(params, opt_cfg)),
                 SamplerStream(sampler))
    t0 = time.time()
    out = tr.run()
    first = tr.metrics_log[0]["loss"]
    print(
        f"\nGraphSAGE on mined communities: loss {first:.3f} -> "
        f"{out['loss']:.3f} in {out['step']} steps ({time.time() - t0:.0f}s)"
    )

    # resume path: restart and train 50 more steps from the checkpoint
    tr2 = Trainer(
        dataclasses.replace(tcfg, total_steps=200), step_fn,
        (params, init_state(params, opt_cfg)), SamplerStream(sampler),
    )
    assert tr2.try_restore() and tr2.step == 150
    out2 = tr2.run()
    print(f"resumed at 150 -> {out2['step']}: loss {out2['loss']:.3f}")


if __name__ == "__main__":
    main()
